//! Cold-start strategy mechanism — the engine half of the sixth policy
//! axis (`coordinator::policy::ColdStartPolicy`; plain data in
//! `crate::coldstart`, design in DESIGN.md "Cold-start strategies").
//!
//! * **Tiered** — nothing in this module runs: every helper is gated on
//!   the `cold_start` knob and the per-function strategy class, so
//!   `cold_start: None` (and the explicit tiered policy) keeps the
//!   historical segmented load path bit-for-bit.
//! * **SnapshotRestore** — `try_snapshot_restore` replaces the bring-up
//!   plan wholesale when the node's host cache holds the function's
//!   snapshot; `on_cold_load_completed` seeds the build after a full
//!   tiered load; `on_snapshot_ready` admits it through the cache
//!   policy (fifth trait); `refresh_snap_gb` keeps the storage
//!   surcharge integrand current (priced in `sim::billing`).
//! * **Pipelined** — `plan_pipelined` shrinks the target's backbone
//!   fetch to `1/K` and `start_pipe_shards` launches the `K-1` sibling
//!   slices as `FlowNet` flows on *their* nodes' links (the speedup is
//!   real link hardware, not accounting); the batch holds in `Loading`
//!   until the last shard lands, and the consolidation transfer —
//!   gathering the sibling slices over the target's NIC — gates
//!   instance release, not TTFT: prefill and decode overlap it.
//!
//! Shards and consolidations carry synthetic flow ids disjoint from
//! batch ids (`>= PIPE_ID_BASE`), so they ride the fair-share machinery
//! — including its retime path — without colliding with load runs.

use std::collections::BTreeMap;

use crate::artifact::{params, LinkKind, PhaseCost, Tier};
use crate::cluster::GpuId;
use crate::coldstart::{snap_key, ColdPath, ColdStartKind, SNAP_PREFIX};
use crate::metrics::Phase;
use crate::sim::engine::Engine;
use crate::sim::events::{EventKind, EventToken};

/// Synthetic flow ids for pipelined shards/consolidations live above
/// every real batch id (batch ids count up from 1).
pub(super) const PIPE_ID_BASE: u64 = 1 << 48;

/// Is this `FlowNet` flow id a pipelined shard or consolidation (as
/// opposed to a batch's own load run)?
pub(super) fn is_pipe_id(id: u64) -> bool {
    id >= PIPE_ID_BASE
}

/// Shard `idx` (0-based, < 15) of the pipelined load owned by `batch`.
fn shard_id(batch: u64, idx: usize) -> u64 {
    debug_assert!(idx < 0xF, "pipeline width exceeds the shard id nibble");
    PIPE_ID_BASE | (batch << 4) | idx as u64
}

/// The consolidation transfer of the pipelined load owned by `batch`
/// (low nibble 0xF, disjoint from every shard index).
fn consol_id(batch: u64) -> u64 {
    PIPE_ID_BASE | (batch << 4) | 0xF
}

/// The owning batch id of a synthetic pipe flow id.
fn pipe_batch(id: u64) -> u64 {
    (id & !PIPE_ID_BASE) >> 4
}

fn is_consol(id: u64) -> bool {
    id & 0xF == 0xF
}

/// The plan for one pipelined cold load, produced by
/// [`Engine::plan_pipelined`] (which already shrank the target's own
/// backbone slice) and consumed by [`Engine::start_pipe_shards`] once
/// the batch exists.
#[derive(Debug, Clone)]
pub(super) struct PipePlan {
    /// Sibling nodes pulling one slice each (node-index order).
    sibling_nodes: Vec<usize>,
    /// The transfer legs of one slice: `(link, solo duration)` — the
    /// same link kinds the target's (tier-resolved) fetch uses, walked
    /// on the *sibling's* node.
    segs: Vec<(LinkKind, f64)>,
    /// Bytes the consolidation pays to gather the sibling slices onto
    /// the target GPU: `payload × (K-1)/K` over the target node's NIC.
    consol_gb: f64,
}

/// One in-flight sibling shard (keyed by its synthetic id in
/// `Engine::pipe_shards`). Removed when its last leg finishes or its
/// run aborts; a live entry always holds a live token and a live flow.
#[derive(Debug, Clone)]
pub(super) struct PipeShard {
    /// The sibling node whose links this shard streams over.
    pub(super) node: usize,
    pub(super) segs: Vec<(LinkKind, f64)>,
    pub(super) cursor: usize,
    /// The completion time currently in the event queue (`token`).
    pub(super) cur_end_s: f64,
    pub(super) token: Option<EventToken>,
}

/// Per-batch pipelined-load state (keyed by the owning batch id in
/// `Engine::pipe_runs`). Lives from dispatch until the batch finalizes
/// (the consolidation gates release) or its run aborts.
#[derive(Debug, Clone)]
pub(super) struct PipeRun {
    pub(super) function: usize,
    /// The target node (consolidation pulls over its NIC).
    pub(super) node: usize,
    pub(super) n_shards: usize,
    pub(super) shards_done: usize,
    /// The target's own (1/K) load slice finished; the batch is holding
    /// in `Loading` for the sibling shards.
    pub(super) own_done: bool,
    /// When the own slice finished — the shard-wait delta folded into
    /// the batch's `BackboneLoad` phase is measured from here.
    pub(super) own_end_s: f64,
    pub(super) consol_gb: f64,
    pub(super) consolidating: bool,
    pub(super) consolidation_done: bool,
    /// The end time currently scheduled for the consolidation event.
    pub(super) consol_end_s: f64,
    pub(super) consol_token: Option<EventToken>,
    /// Decode finished while the consolidation was still in flight; the
    /// `ConsolidateDone` event re-enters `finalize_batch`.
    pub(super) done_pending: bool,
}

impl Engine {
    // ------------------------------------------------- snapshot-restore

    /// SnapStart path of `make_resident`: if function `f` uses the
    /// snapshot-restore strategy and its snapshot sits in the node's
    /// host cache, replace the whole bring-up plan with the restore —
    /// a fixed re-hydration plus one PCIe stream of the snapshot body
    /// (still a contended flow). Returns whether it hit.
    pub(super) fn try_snapshot_restore(
        &mut self,
        f: usize,
        gpu: GpuId,
        plan: &mut BTreeMap<Phase, PhaseCost>,
    ) -> bool {
        if self.cold_start.strategy(f) != ColdStartKind::SnapshotRestore {
            return false;
        }
        // Only a cold backbone bring-up restores; a warm (or RAM-staged,
        // transfer-free) dispatch is already cheaper than any restore.
        if !plan.get(&Phase::BackboneLoad).map_or(false, PhaseCost::has_xfer) {
            return false;
        }
        let (key, gb) = {
            let spec = &self.functions[f];
            (snap_key(&spec.name), spec.model.weights_gb + params::CUDA_CONTEXT_GB)
        };
        let cache = &mut self.cluster.nodes[gpu.node].cache;
        if !cache.enabled() || !cache.contains(key) {
            return false;
        }
        self.cache.on_hit(cache, key, self.now);
        let restore_s = self.cold_start.snapshot().restore_s;
        plan.clear();
        plan.insert(Phase::ContainerInit, PhaseCost::fixed(restore_s));
        plan.insert(Phase::BackboneLoad, PhaseCost::xfer(LinkKind::Pcie, gb));
        self.stats.snapshot_restores += 1;
        true
    }

    /// A cold bring-up completed (`complete_load`). Clears any
    /// crash-forced tiered fallback for `f`, and — for a
    /// snapshot-restore function whose load took the full tiered path —
    /// seeds the snapshot build: `build_s` later a `SnapshotReady`
    /// event offers it to the node's cache. At most one build per
    /// `(function, node)` is ever in flight.
    pub(super) fn on_cold_load_completed(&mut self, f: usize, node: usize, cold_path: ColdPath) {
        self.pipe_fallback.remove(&f);
        if cold_path != ColdPath::Tiered
            || self.cold_start.strategy(f) != ColdStartKind::SnapshotRestore
            || self.cfg.tiers.is_none()
        {
            return;
        }
        if !self.cluster.nodes[node].cache.enabled() {
            return;
        }
        let key = snap_key(&self.functions[f].name);
        if self.cluster.nodes[node].cache.contains(key)
            || self.snap_builds.contains_key(&(f, node))
        {
            return;
        }
        let build_s = self.cold_start.snapshot().build_s;
        self.stats.snapshot_builds += 1;
        let tok = self.events.push(self.now + build_s, EventKind::SnapshotReady(f, node));
        self.snap_builds.insert((f, node), tok);
    }

    /// The snapshot of `f` finished serializing on `node`: offer it to
    /// the host cache through the cache policy. The policy may evict to
    /// make room or decline outright (both counted); admission flips
    /// the surcharge integrand.
    pub(super) fn on_snapshot_ready(&mut self, f: usize, node: usize) {
        self.snap_builds.remove(&(f, node));
        let (key, gb) = {
            let spec = &self.functions[f];
            (snap_key(&spec.name), spec.model.weights_gb + params::CUDA_CONTEXT_GB)
        };
        let cache = &mut self.cluster.nodes[node].cache;
        let evicted = self.cache.admit(cache, key, gb, self.now);
        self.stats.cache_evictions += evicted;
        if self.cluster.nodes[node].cache.contains(key) {
            self.stats.snapshots_built += 1;
        } else {
            self.stats.snapshot_builds_declined += 1;
        }
        self.refresh_snap_gb();
    }

    /// Recompute the resident-snapshot GB total (the storage-surcharge
    /// integrand, integrated by `bill_interval`) from the node caches.
    /// Called after every ledger mutation that can touch `snap:` keys;
    /// a `cold_start: None` run returns before any float work.
    pub(super) fn refresh_snap_gb(&mut self) {
        if self.cfg.cold_start.is_none() {
            return;
        }
        self.snap_gb_total = self
            .cluster
            .nodes
            .iter()
            .map(|n| n.cache.prefixed_gb(SNAP_PREFIX))
            .sum();
    }

    // ------------------------------------------------- pipelined loads

    /// Pipelined path of `dispatch`: if function `f` uses the pipelined
    /// strategy, its (tier-resolved) backbone fetch reads below host
    /// RAM, and at least one other up node has an idle up GPU, shrink
    /// the plan's backbone slice to `1/K_eff` and return the shard plan
    /// for `start_pipe_shards`. A crash-forced fallback (`pipe_fallback`)
    /// retries tiered instead.
    pub(super) fn plan_pipelined(
        &mut self,
        f: usize,
        gpu: GpuId,
        plan: &mut BTreeMap<Phase, PhaseCost>,
    ) -> Option<PipePlan> {
        if self.cold_start.strategy(f) != ColdStartKind::Pipelined
            || self.pipe_fallback.contains(&f)
        {
            return None;
        }
        let k = self.cold_start.pipeline().k;
        if k < 2 {
            return None;
        }
        // A RAM-or-better source (host cache hit, staged copy) is a
        // single PCIe stream — splitting it across nodes would *add* a
        // network consolidation for nothing.
        if !plan.get(&Phase::BackboneLoad).map_or(false, PhaseCost::fetches_below_ram) {
            return None;
        }
        // Sibling nodes in index order: up, not the target, with at
        // least one idle up GPU to stage the slice into.
        let mut sibling_nodes = Vec::new();
        for node in &self.cluster.nodes {
            if node.id == gpu.node || !self.cluster.node_is_up(node.id) {
                continue;
            }
            let has_idle = node.gpus.iter().any(|g| {
                self.cluster.gpu_is_up(g.id) && self.gpu_busy[self.gpu_map.dense(g.id)] == 0
            });
            if has_idle {
                sibling_nodes.push(node.id);
                if sibling_nodes.len() == k - 1 {
                    break;
                }
            }
        }
        if sibling_nodes.is_empty() {
            return None;
        }
        let k_eff = 1 + sibling_nodes.len();
        let caps = self.cfg.tiers.expect("pipelined requires tiers").caps();
        let cost = plan.get_mut(&Phase::BackboneLoad).expect("checked above");
        let payload = cost.payload_gb();
        cost.scale(1.0 / k_eff as f64);
        let segs: Vec<(LinkKind, f64)> = cost
            .0
            .iter()
            .filter_map(|t| match *t {
                crate::artifact::Term::Xfer { link, gb } if gb > 0.0 => {
                    Some((link, gb / caps.gbps(link)))
                }
                _ => None,
            })
            .collect();
        debug_assert!(!segs.is_empty(), "a below-RAM fetch has transfer legs");
        let consol_gb = payload * (k_eff - 1) as f64 / k_eff as f64;
        Some(PipePlan { sibling_nodes, segs, consol_gb })
    }

    /// Launch the sibling shards of `batch_id`'s pipelined load (after
    /// the target's own scaled run joined its links, so join order —
    /// and every retime it causes — is deterministic). Sibling GPUs are
    /// *not* marked busy: the slice DMA-streams into idle HBM and the
    /// router may still dispatch onto them (their links contend, which
    /// is the honest cost).
    pub(super) fn start_pipe_shards(&mut self, batch_id: u64, pipe: PipePlan) {
        let (f, node) = {
            let b = &self.batches[&batch_id];
            (b.function, b.gpu.node)
        };
        self.stats.pipelined_loads += 1;
        self.stats.pipelined_shards += pipe.sibling_nodes.len() as u64;
        self.pipe_runs.insert(
            batch_id,
            PipeRun {
                function: f,
                node,
                n_shards: pipe.sibling_nodes.len(),
                shards_done: 0,
                own_done: false,
                own_end_s: 0.0,
                consol_gb: pipe.consol_gb,
                consolidating: false,
                consolidation_done: false,
                consol_end_s: 0.0,
                consol_token: None,
                done_pending: false,
            },
        );
        for (idx, &sib) in pipe.sibling_nodes.iter().enumerate() {
            let sid = shard_id(batch_id, idx);
            self.pipe_shards.insert(
                sid,
                PipeShard {
                    node: sib,
                    segs: pipe.segs.clone(),
                    cursor: 0,
                    cur_end_s: 0.0,
                    token: None,
                },
            );
            self.start_shard_segment(sid);
        }
    }

    /// Join the current leg of shard `sid` onto its sibling node's link.
    fn start_shard_segment(&mut self, sid: u64) {
        let (node, link, dur) = {
            let s = &self.pipe_shards[&sid];
            let (link, dur) = s.segs[s.cursor];
            (s.node, link, dur)
        };
        let (end, retimes) = self.flows.join(node, link, sid, dur, self.now + dur, self.now);
        let tok = self.events.push(end, EventKind::ShardDone(sid));
        let s = self.pipe_shards.get_mut(&sid).expect("shard exists");
        s.cur_end_s = end;
        s.token = Some(tok);
        self.apply_load_retimes(retimes);
    }

    /// A shard leg finished. Advance to the next leg, or retire the
    /// shard: count it toward its run, start the consolidation once the
    /// trigger fraction of shards has landed, and — when the last shard
    /// meets an already-finished target slice — fold the wait into the
    /// batch's `BackboneLoad` phase and complete the load.
    pub(super) fn on_shard_done(&mut self, sid: u64) {
        let (node, link) = {
            let s = &self.pipe_shards[&sid];
            (s.node, s.segs[s.cursor].0)
        };
        let (_, retimes) = self.flows.finish(node, link, sid, self.now);
        self.apply_load_retimes(retimes);
        let retired = {
            let s = self.pipe_shards.get_mut(&sid).expect("shard exists");
            s.token = None;
            s.cursor += 1;
            s.cursor == s.segs.len()
        };
        if !retired {
            return self.start_shard_segment(sid);
        }
        self.pipe_shards.remove(&sid);
        let batch_id = pipe_batch(sid);
        let frac = self.cold_start.pipeline().consolidate_frac;
        let (start_consol, all_landed) = {
            let run = self.pipe_runs.get_mut(&batch_id).expect("shard without a pipe run");
            run.shards_done += 1;
            let trigger = ((frac * run.n_shards as f64).ceil() as usize).max(1);
            (
                !run.consolidating && !run.consolidation_done && run.shards_done >= trigger,
                run.shards_done == run.n_shards && run.own_done,
            )
        };
        if start_consol {
            self.start_consolidation(batch_id);
        }
        if all_landed {
            let delta = {
                let run = &self.pipe_runs[&batch_id];
                self.now - run.own_end_s
            };
            // Prefill needed the shard tail: attribute the wait to the
            // backbone phase so TTFT stays the sum of its phases. An
            // exactly-synchronous landing adds no term.
            if delta != 0.0 {
                let batch = self.batches.get_mut(&batch_id).expect("batch exists");
                *batch.load_phases.entry(Phase::BackboneLoad).or_insert(0.0) += delta;
            }
            self.complete_load(batch_id);
        }
    }

    /// Start the consolidation transfer: one flow of `consol_gb` over
    /// the target node's NIC (the sibling slices stream back across the
    /// datacenter network), contending fairly with any other load.
    fn start_consolidation(&mut self, batch_id: u64) {
        let (node, gb) = {
            let run = &self.pipe_runs[&batch_id];
            (run.node, run.consol_gb)
        };
        let caps = self.cfg.tiers.expect("pipelined requires tiers").caps();
        let dur = gb / caps.gbps(LinkKind::Nic);
        let cid = consol_id(batch_id);
        let (end, retimes) =
            self.flows.join(node, LinkKind::Nic, cid, dur, self.now + dur, self.now);
        let tok = self.events.push(end, EventKind::ConsolidateDone(cid));
        let run = self.pipe_runs.get_mut(&batch_id).expect("pipe run exists");
        run.consolidating = true;
        run.consol_end_s = end;
        run.consol_token = Some(tok);
        self.apply_load_retimes(retimes);
    }

    /// The consolidation landed: every byte of the checkpoint now sits
    /// on the target GPU. If decode already finished (`done_pending`),
    /// the batch finalizes now.
    pub(super) fn on_consolidate_done(&mut self, cid: u64) {
        let batch_id = pipe_batch(cid);
        let node = self.pipe_runs[&batch_id].node;
        let (_, retimes) = self.flows.finish(node, LinkKind::Nic, cid, self.now);
        self.apply_load_retimes(retimes);
        let finalize = {
            let run = self.pipe_runs.get_mut(&batch_id).expect("pipe run exists");
            run.consolidating = false;
            run.consolidation_done = true;
            run.consol_token = None;
            run.done_pending
        };
        self.stats.pipeline_consolidations += 1;
        if finalize {
            self.finalize_batch(batch_id);
        }
    }

    /// `on_load_done` hook: the target's own slice is done — hold the
    /// batch in `Loading` while sibling shards are still streaming
    /// (`on_shard_done` completes the load), else proceed.
    pub(super) fn pipe_hold_for_shards(&mut self, batch_id: u64) -> bool {
        let Some(run) = self.pipe_runs.get_mut(&batch_id) else { return false };
        run.own_done = true;
        run.own_end_s = self.now;
        run.shards_done < run.n_shards
    }

    /// `finalize_batch` hook: a pipelined instance cannot release until
    /// its consolidation lands. Defers (the `ConsolidateDone` event
    /// re-enters) or retires the run and lets finalization proceed.
    pub(super) fn pipe_defer_finalize(&mut self, batch_id: u64) -> bool {
        let Some(run) = self.pipe_runs.get_mut(&batch_id) else { return false };
        if !run.consolidation_done {
            run.done_pending = true;
            return true;
        }
        self.pipe_runs.remove(&batch_id);
        false
    }

    /// A `FlowNet` retime hit a synthetic pipe flow: re-arm its own
    /// event kind (shards and consolidations never ride `LoadDone`).
    pub(super) fn retime_pipe_flow(&mut self, id: u64, end_s: f64) {
        if is_consol(id) {
            let run = self
                .pipe_runs
                .get_mut(&pipe_batch(id))
                .expect("retimed consolidation has a run");
            if let Some(tok) = run.consol_token.take() {
                self.events.cancel(tok);
            }
            run.consol_end_s = end_s;
            run.consol_token = Some(self.events.push(end_s, EventKind::ConsolidateDone(id)));
        } else {
            let s = self.pipe_shards.get_mut(&id).expect("retimed shard exists");
            if let Some(tok) = s.token.take() {
                self.events.cancel(tok);
            }
            s.cur_end_s = end_s;
            s.token = Some(self.events.push(end_s, EventKind::ShardDone(id)));
        }
        self.stats.load_retimes += 1;
    }

    /// Tear down `batch_id`'s pipelined run (load failure or crash):
    /// cancel shard and consolidation events, pull their flows off the
    /// links (survivors re-time at their fatter share), and force the
    /// function's next cold start onto the tiered path. Idempotent —
    /// a batch without a pipe run is a no-op.
    pub(super) fn abort_pipe_run(&mut self, batch_id: u64) {
        let Some(run) = self.pipe_runs.remove(&batch_id) else { return };
        for idx in 0..run.n_shards {
            let sid = shard_id(batch_id, idx);
            if let Some(shard) = self.pipe_shards.remove(&sid) {
                if let Some(tok) = shard.token {
                    self.events.cancel(tok);
                }
                let (link, _) = shard.segs[shard.cursor];
                let (_, retimes) = self.flows.finish(shard.node, link, sid, self.now);
                self.apply_load_retimes(retimes);
            }
        }
        if run.consolidating {
            if let Some(tok) = run.consol_token {
                self.events.cancel(tok);
            }
            let (_, retimes) =
                self.flows.finish(run.node, LinkKind::Nic, consol_id(batch_id), self.now);
            self.apply_load_retimes(retimes);
        }
        self.stats.pipeline_cancellations += 1;
        self.pipe_fallback.insert(run.function);
    }

    /// Is this `Loading` batch holding for sibling shards (its own load
    /// run already retired)? Used by the flow invariants.
    pub(super) fn pipe_held(&self, batch_id: u64) -> bool {
        self.pipe_runs.get(&batch_id).map_or(false, |r| r.own_done)
    }

    // ---------------------------------------------------- fault plumbing

    /// A node (or a GPU and therefore its worker process) failed:
    /// cancel snapshot builds serializing on it (the memfd died with
    /// the process; the cache wipe already dropped finished snapshots)
    /// and kill the pipelined runs streaming a shard from it — their
    /// batches redispatch, falling back to the tiered path.
    pub(super) fn coldstart_node_failed(&mut self, node: usize) {
        if self.cfg.cold_start.is_none() {
            return;
        }
        let builds: Vec<(usize, usize)> = self
            .snap_builds
            .keys()
            .copied()
            .filter(|&(_, n)| n == node)
            .collect();
        for key in builds {
            let tok = self.snap_builds.remove(&key).expect("listed build exists");
            self.events.cancel(tok);
            self.stats.snapshot_builds_cancelled += 1;
        }
        let mut victims: Vec<u64> = self
            .pipe_shards
            .iter()
            .filter(|(_, s)| s.node == node)
            .map(|(&sid, _)| pipe_batch(sid))
            .collect();
        victims.sort_unstable();
        victims.dedup();
        for b in victims {
            self.kill_batch(b);
        }
        self.refresh_snap_gb();
    }

    // -------------------------------------------------------- invariants

    /// Brute-force cold-start invariants, called from `check_indexes`:
    /// build/shard/consolidation events mirror their trackers exactly
    /// (bit-equal scheduled times, matching flows), the snapshot-build
    /// counters conserve, and the surcharge integrand equals its ledger
    /// recomputation.
    pub(super) fn check_coldstart(&self) {
        let snap_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::SnapshotReady(..)))
            .count();
        assert_eq!(snap_events, self.snap_builds.len(), "untracked SnapshotReady events");
        for (&(f, node), &tok) in &self.snap_builds {
            let p = self.events.get(tok).expect("tracked SnapshotReady token is dead");
            assert!(
                matches!(p.kind, &EventKind::SnapshotReady(ef, en) if ef == f && en == node),
                "build token for ({f}, {node}) points at {:?}",
                p.kind
            );
        }
        assert_eq!(
            self.stats.snapshot_builds,
            self.stats.snapshots_built
                + self.stats.snapshot_builds_cancelled
                + self.stats.snapshot_builds_declined
                + self.snap_builds.len() as u64,
            "snapshot builds do not conserve"
        );
        let shard_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::ShardDone(_)))
            .count();
        assert_eq!(shard_events, self.pipe_shards.len(), "untracked ShardDone events");
        for (&sid, s) in &self.pipe_shards {
            assert!(s.cursor < s.segs.len(), "shard cursor past end for {sid}");
            let tok = s.token.expect("live shard without a token");
            let p = self.events.get(tok).expect("tracked ShardDone token is dead");
            assert!(
                matches!(p.kind, &EventKind::ShardDone(id) if id == sid),
                "shard token for {sid} points at {:?}",
                p.kind
            );
            assert_eq!(
                p.t.to_bits(),
                s.cur_end_s.to_bits(),
                "scheduled shard event drifted for {sid}"
            );
            let (link, _) = s.segs[s.cursor];
            let end = self
                .flows
                .scheduled_end(s.node, link, sid)
                .expect("live shard without a flow");
            assert_eq!(
                end.to_bits(),
                s.cur_end_s.to_bits(),
                "shard flow/event times diverged for {sid}"
            );
            assert!(
                self.pipe_runs.contains_key(&pipe_batch(sid)),
                "orphan shard {sid}"
            );
        }
        let consol_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::ConsolidateDone(_)))
            .count();
        let consolidating = self.pipe_runs.values().filter(|r| r.consolidating).count();
        assert_eq!(consol_events, consolidating, "untracked ConsolidateDone events");
        for (&b, run) in &self.pipe_runs {
            assert!(self.batches.contains_key(&b), "pipe run without a batch {b}");
            assert!(run.shards_done <= run.n_shards, "over-counted shards for {b}");
            if run.consolidating {
                let tok = run.consol_token.expect("consolidating run without a token");
                let p = self.events.get(tok).expect("tracked ConsolidateDone token is dead");
                assert!(
                    matches!(p.kind, &EventKind::ConsolidateDone(id) if id == consol_id(b)),
                    "consolidation token for {b} points at {:?}",
                    p.kind
                );
                assert_eq!(
                    p.t.to_bits(),
                    run.consol_end_s.to_bits(),
                    "scheduled consolidation drifted for {b}"
                );
            } else {
                assert!(run.consol_token.is_none(), "idle consolidation holds a token");
            }
        }
        let brute: f64 = self
            .cluster
            .nodes
            .iter()
            .map(|n| n.cache.prefixed_gb(SNAP_PREFIX))
            .sum();
        assert_eq!(
            brute.to_bits(),
            self.snap_gb_total.to_bits(),
            "snapshot surcharge integrand drifted"
        );
    }
}

/// A restored backbone is sourced from host RAM by construction.
#[allow(dead_code)]
pub(super) const RESTORE_TIER: Tier = Tier::ContainerRam;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{FunctionSpec, ModelProfile};
    use crate::cluster::Cluster;
    use crate::coldstart::ColdStartSpec;
    use crate::sim::config::{SystemConfig, TierSpec};
    use crate::sim::engine::{Engine, Workload};
    use crate::trace::Request;

    /// `n` requests to one function, spaced far beyond keep-alive — every
    /// request is an isolated cold start.
    fn spaced(n: usize, gap_s: f64) -> Workload {
        let functions = vec![FunctionSpec::new(0, ModelProfile::llama2_7b(), 0)];
        let requests: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i as u64,
                function: 0,
                arrival_s: i as f64 * gap_s,
                prompt_tokens: 256,
                output_tokens: 64,
            })
            .collect();
        Workload {
            functions,
            requests,
            duration_s: n as f64 * gap_s,
            rates: vec![1.0 / gap_s],
        }
    }

    fn run_checked(mut e: Engine) -> Engine {
        let mut steps = 0u64;
        while e.step() {
            steps += 1;
            if steps % 5 == 0 {
                e.check_indexes();
            }
        }
        e.check_indexes();
        e
    }

    #[test]
    fn snapshot_restore_beats_tiered_on_repeat_colds() {
        let w = spaced(4, 400.0);
        let tiered_cfg = SystemConfig::npl().with_tiers(TierSpec::default());
        let snap_cfg = tiered_cfg
            .clone()
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::SnapshotRestore));
        let (mt, ct, _) =
            Engine::new(tiered_cfg, Cluster::new(1, 2, 4), w.clone(), 1).run();
        let e = run_checked(Engine::new(snap_cfg, Cluster::new(1, 2, 4), w, 1));
        assert!(e.stats.snapshot_builds >= 1, "first cold load must seed a build");
        assert!(e.stats.snapshots_built >= 1, "the build never landed in cache");
        assert!(e.stats.snapshot_restores >= 2, "repeat colds must restore");
        let (ms, cs, _) = e.finish();
        assert_eq!(ms.outcomes.len(), mt.outcomes.len());
        let t0 = mt.outcomes.iter().find(|o| o.id == 0).unwrap();
        let s0 = ms.outcomes.iter().find(|o| o.id == 0).unwrap();
        assert_eq!(s0.cold_path, ColdPath::Tiered, "first touch takes the tiered path");
        assert_eq!(
            s0.ttft_s.to_bits(),
            t0.ttft_s.to_bits(),
            "the seeding load is the tiered path bit-for-bit"
        );
        for id in [1u64, 2, 3] {
            let t = mt.outcomes.iter().find(|o| o.id == id).unwrap();
            let s = ms.outcomes.iter().find(|o| o.id == id).unwrap();
            assert_eq!(s.cold_path, ColdPath::SnapshotRestore, "request {id}");
            assert!(
                s.ttft_s < t.ttft_s,
                "restore must beat the tiered repeat cold: {} vs {} (request {id})",
                s.ttft_s,
                t.ttft_s
            );
        }
        assert!(cs.snapshot_usd > 0.0, "resident snapshot must bill storage");
        assert_eq!(ct.snapshot_usd, 0.0, "tiered runs pay no surcharge");
        assert!(cs.total_usd() > 0.0);
    }

    #[test]
    fn pipelined_splits_first_touch_across_nodes() {
        let w = spaced(1, 200.0);
        let base = SystemConfig::npl().with_tiers(TierSpec::default());
        let pipe_cfg = base
            .clone()
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::Pipelined));
        let (mt, _, _) = Engine::new(base, Cluster::new(4, 1, 4), w.clone(), 1).run();
        let e = run_checked(Engine::new(pipe_cfg, Cluster::new(4, 1, 4), w, 1));
        assert_eq!(e.stats.pipelined_loads, 1);
        assert_eq!(e.stats.pipelined_shards, 3, "k=4 means 3 sibling shards");
        assert_eq!(e.stats.pipeline_consolidations, 1);
        assert_eq!(e.stats.pipeline_cancellations, 0);
        assert!(e.pipe_runs.is_empty() && e.pipe_shards.is_empty());
        let (mp, _, _) = e.finish();
        let t = &mt.outcomes[0];
        let p = &mp.outcomes[0];
        assert_eq!(p.cold_path, ColdPath::Pipelined);
        assert!(
            p.ttft_s < t.ttft_s,
            "a 4-way split must beat the solo tiered first touch: {} vs {}",
            p.ttft_s,
            t.ttft_s
        );
        assert!(
            p.e2e_s > p.ttft_s,
            "the consolidation tail gates release, not first token"
        );
    }

    #[test]
    fn pipelined_narrow_cluster_falls_back_to_tiered() {
        // One node: no siblings exist, so the pipelined strategy
        // degrades to the tiered path (width 1) with zero pipe state.
        let w = spaced(2, 400.0);
        let base = SystemConfig::npl().with_tiers(TierSpec::default());
        let cfg = base
            .clone()
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::Pipelined));
        let (mt, _, _) = Engine::new(base, Cluster::new(1, 2, 4), w.clone(), 1).run();
        let e = run_checked(Engine::new(cfg, Cluster::new(1, 2, 4), w, 1));
        assert_eq!(e.stats.pipelined_loads, 0, "no siblings, no pipeline");
        let (mp, _, _) = e.finish();
        for (a, b) in mt.outcomes.iter().zip(&mp.outcomes) {
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "request {}", a.id);
        }
    }

    #[test]
    fn node_failure_mid_build_cancels_and_rebuilds() {
        let cfg = SystemConfig::npl()
            .with_tiers(TierSpec::default())
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::SnapshotRestore));
        let w = spaced(3, 400.0);
        let n = w.requests.len();
        let mut e = Engine::new(cfg, Cluster::new(1, 2, 4), w, 1);
        while e.snap_builds.is_empty() {
            assert!(e.step(), "a build never started");
        }
        e.check_indexes();
        e.coldstart_node_failed(0);
        assert!(e.snap_builds.is_empty(), "the in-flight build must cancel");
        assert_eq!(e.stats.snapshot_builds_cancelled, 1);
        e.check_indexes();
        while e.step() {}
        e.check_indexes();
        assert!(e.stats.snapshot_builds >= 2, "the next cold load must re-seed");
        assert!(e.stats.snapshots_built >= 1);
        assert!(e.stats.snapshot_restores >= 1, "the rebuilt snapshot must serve");
        let (m, _, _) = e.finish();
        assert_eq!(m.outcomes.len(), n);
    }

    #[test]
    fn crash_mid_consolidation_cancels_and_falls_back() {
        use crate::sim::fault::FaultSpec;
        // Dormant injector: the retry plumbing exists, nothing fires on
        // its own — the kill below is the only fault.
        let cfg = SystemConfig::npl()
            .with_tiers(TierSpec::default())
            .with_cold_start(ColdStartSpec::uniform(ColdStartKind::Pipelined))
            .with_faults(FaultSpec {
                mtbf_s: 1e15,
                load_fail_prob: 0.0,
                ..FaultSpec::default()
            });
        let w = spaced(1, 400.0);
        let mut e = Engine::new(cfg, Cluster::new(4, 1, 4), w, 1);
        while !e.pipe_runs.values().any(|r| r.consolidating) {
            assert!(e.step(), "a consolidation never started");
        }
        e.check_indexes();
        let (&b, _) = e.pipe_runs.iter().next().expect("run exists");
        e.kill_batch(b);
        e.check_indexes();
        assert!(e.pipe_runs.is_empty() && e.pipe_shards.is_empty());
        assert_eq!(e.stats.pipeline_cancellations, 1);
        assert_eq!(e.stats.pipeline_consolidations, 0, "cancelled before landing");
        assert!(e.pipe_fallback.contains(&0), "the retry must fall back to tiered");
        let mut steps = 0u64;
        while e.step() {
            steps += 1;
            if steps % 5 == 0 {
                e.check_indexes();
            }
        }
        e.check_indexes();
        assert_eq!(e.stats.pipelined_loads, 1, "the retry must not re-pipeline");
        let (m, _, st) = e.finish();
        assert!(st.redispatched >= 1, "the killed batch must redispatch");
        assert_eq!(m.outcomes.len() + m.failed as usize, 1, "conservation");
        if let Some(o) = m.outcomes.first() {
            assert_eq!(o.cold_path, ColdPath::Tiered, "fallback path on retry");
        }
    }
}
