//! Event-integrated billing on **delta-maintained aggregates**.
//!
//! Historically the engine sampled every GPU's billable state between
//! *every pair of events* — an O(G) walk (plus a batch scan for loading
//! GPUs and a `resident_functions()` allocation on idle ones) that became
//! the densest per-event path once the event core went O(1). Both §6.1
//! pricing rules (serverless GB·s, serverful flat) are linear within a
//! billing class, so the engine now keeps each GPU classified into one of
//! a small set of classes and maintains running per-class sums (count,
//! Σ used, Σ capacity); `bill_interval` hands the [`BillingModel`] one
//! [`AggregateBillSample`] per interval — O(1) per event regardless of
//! fleet size.
//!
//! ## Classes
//!
//! * **Empty** — no billable bytes above the runtime reserve; never
//!   billed (and never sampled).
//! * **ActiveExec** — at least one executing batch.
//! * **ActiveLoading** — an in-flight artifact load but nothing
//!   executing; bills like execution (the instance is allocated and
//!   working).
//! * **IdleWarm** — idle, hosting ≥1 keep-alive-warm function; bills
//!   idle GB·s (§2.2 keep-alive wastage).
//! * **IdleCold** — idle, residency entirely agent-staged; not billed to
//!   users (§2.4 "pre-loading without extra wastage").
//!
//! ## Maintenance
//!
//! Every state change funnels through the [`Engine::reclassify_gpu`]
//! choke point, O(1) per call:
//!
//! * **memory deltas** (`load_artifact`/`evict`/KV/context/shared
//!   segments, including policy-internal mutations) mark the GPU in the
//!   cluster's `bill_dirty` channel via `gpu_mut`; the engine drains it
//!   once at the end of each event;
//! * **exec start/finish** reclassify from `schedule_tick` (called after
//!   every exec mutation);
//! * **batch Loading→Prefill transitions** maintain the per-GPU
//!   `gpu_loading` count and reclassify at both ends;
//! * **keep-alive warm/cold transitions** adjust the per-GPU warm-count
//!   aggregate over the function's resident GPUs.
//!
//! The idle-GPU warm test reads the warm-count aggregate — refreshed
//! from the cluster's per-GPU residency *snapshot* on memory changes —
//! so the old `Gpu::resident_functions()` BTreeSet allocation is gone
//! from the billing path entirely.
//!
//! ## Exactness
//!
//! Σ used is tracked in integer **milli-GB** (quantized once per GPU per
//! reclassification, converted to GB at the sample boundary): integer
//! deltas cannot drift, so the running sums stay bit-identical to a
//! brute-force rebuild over the whole run — `Engine::check_billing`
//! asserts exactly that, and a cfg(test) oracle mode re-derives every
//! sample by full scan for the differential cost tests.

use std::collections::{BTreeMap, BTreeSet};
use std::time::Instant;

use crate::artifact::params;
use crate::cluster::GpuId;
use crate::coordinator::policy::{AggregateBillSample, ClassBillSample};
use crate::sim::dispatch::BatchState;
use crate::sim::engine::Engine;
use crate::sim::observe::Observer;

/// Quantize GB to integer milli-GB (the aggregate's fixed-point unit).
/// Sub-milli-GB residue (f64 ledger noise) rounds to zero instead of
/// accumulating in the running sums.
fn milli_gb(gb: f64) -> i64 {
    (gb * 1000.0).round() as i64
}

/// The billing classes (see module docs). Discriminants index
/// [`BillingIndex::sums`]. Public: observer hooks
/// (`sim::observe::Observer::on_gpu_reclass`) report class transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BillClass {
    Empty = 0,
    ActiveExec = 1,
    ActiveLoading = 2,
    IdleWarm = 3,
    IdleCold = 4,
}

const N_CLASSES: usize = 5;

fn classify(used_milli: i64, executing: bool, loading: bool, warm: bool) -> BillClass {
    if used_milli <= 0 {
        BillClass::Empty
    } else if executing {
        BillClass::ActiveExec
    } else if loading {
        BillClass::ActiveLoading
    } else if warm {
        BillClass::IdleWarm
    } else {
        BillClass::IdleCold
    }
}

/// One GPU's current contribution to the class sums.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct GpuBillState {
    pub(super) class: BillClass,
    pub(super) used_milli: i64,
    pub(super) total_milli: i64,
}

/// Running totals for one class.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub(super) struct ClassSums {
    pub(super) count: usize,
    pub(super) used_milli: i64,
    pub(super) total_milli: i64,
}

impl ClassSums {
    fn add(&mut self, s: GpuBillState) {
        self.count += 1;
        self.used_milli += s.used_milli;
        self.total_milli += s.total_milli;
    }

    fn sub(&mut self, s: GpuBillState) {
        self.count -= 1;
        self.used_milli -= s.used_milli;
        self.total_milli -= s.total_milli;
    }
}

/// The engine's billing aggregates: per-GPU classification mirror, the
/// per-class running sums, and the keep-alive warm-set bookkeeping.
#[derive(Debug, Default)]
pub(super) struct BillingIndex {
    /// GPU → its counted class + quantized footprint.
    state: BTreeMap<GpuId, GpuBillState>,
    /// Per-class (count, Σ used milli-GB, Σ capacity milli-GB).
    sums: [ClassSums; N_CLASSES],
    /// Mirror of the keep-alive window set (`KeepAlive::contains`):
    /// inserted on touch, removed when the sweep pops the window.
    warm_fns: BTreeSet<usize>,
    /// GPU → number of warm functions resident there (absent = 0). The
    /// idle-warm class test is an O(log) lookup here.
    warm_on: BTreeMap<GpuId, usize>,
    /// Reused drain buffer (swapped with the cluster's `bill_dirty`
    /// channel each event, so neither side re-allocates on the hot
    /// path).
    scratch: Vec<GpuId>,
    /// Measure the split billing wall-clock meters (fleet bench only —
    /// `Instant` calls are not free at millions of events per second).
    timed: bool,
    /// cfg(test): derive every sample from a brute-force scan instead of
    /// the running sums (the differential cost oracle).
    #[cfg(test)]
    pub(super) via_oracle: bool,
}

impl BillingIndex {
    /// Install one GPU's state, folding the delta into the class sums.
    /// Returns the displaced state so the caller can report class
    /// *transitions* to observers.
    fn set(&mut self, g: GpuId, new: GpuBillState) -> Option<GpuBillState> {
        let old = self.state.insert(g, new);
        if let Some(old) = old {
            self.sums[old.class as usize].sub(old);
        }
        self.sums[new.class as usize].add(new);
        old
    }

    fn remove(&mut self, g: GpuId) {
        if let Some(old) = self.state.remove(&g) {
            self.sums[old.class as usize].sub(old);
        }
        self.warm_on.remove(&g);
    }

    fn warm_here(&self, g: GpuId) -> bool {
        self.warm_on.contains_key(&g)
    }

    fn sample(sums: &[ClassSums; N_CLASSES]) -> AggregateBillSample {
        let class = |c: BillClass| {
            let s = &sums[c as usize];
            ClassBillSample {
                gpus: s.count,
                used_gb: s.used_milli as f64 / 1000.0,
                total_gb: s.total_milli as f64 / 1000.0,
            }
        };
        AggregateBillSample {
            active: class(BillClass::ActiveExec),
            loading: class(BillClass::ActiveLoading),
            idle_warm: class(BillClass::IdleWarm),
            idle_cold: class(BillClass::IdleCold),
        }
    }
}

impl Engine {
    /// Integrate cost over `[last_bill_t, until)`: one aggregate sample,
    /// priced by the built-in [`BilledCost`] observer — no per-GPU work.
    /// The same sample then fans out to the opt-in series sampler and
    /// any attached observers (after the built-in, so extras can never
    /// perturb the money path).
    ///
    /// [`BilledCost`]: crate::sim::observe::BilledCost
    pub(super) fn bill_interval(&mut self, until: f64) {
        let dt = until - self.last_bill_t;
        if dt <= 0.0 || !self.cost_obs.model.needs_interval() {
            self.last_bill_t = until.max(self.last_bill_t);
            return;
        }
        let t0 = self.last_bill_t;
        let timer = self.bill.timed.then(Instant::now);
        let sample = self.bill_sample();
        Observer::on_bill_sample(&mut self.cost_obs, t0, dt, &sample);
        self.stats.bill_samples += 1;
        self.last_bill_t = until;
        if let Some(s) = self.series.as_mut() {
            s.on_bill_sample(t0, dt, &sample);
        }
        for ob in &mut self.observers {
            ob.on_bill_sample(t0, dt, &sample);
        }
        // The meter covers the whole per-sample path: production,
        // pricing, and the fan-out to the series sampler / attached
        // observers — so enabling a sink shows up in the trajectory.
        if let Some(timer) = timer {
            self.stats.bill_sample_wall_s += timer.elapsed().as_secs_f64();
        }
    }

    fn bill_sample(&self) -> AggregateBillSample {
        #[cfg(test)]
        if self.bill.via_oracle {
            let (_, sums, _, _) = self.brute_bill();
            return BillingIndex::sample(&sums);
        }
        BillingIndex::sample(&self.bill.sums)
    }

    /// Measure billing wall-clock into the split meters
    /// (`RunStats::bill_sample_wall_s` for sampling + pricing,
    /// `bill_reclass_wall_s` for class maintenance) — the fleet bench's
    /// "billing share" record. Off by default.
    pub fn set_bill_timing(&mut self, on: bool) {
        self.bill.timed = on;
    }

    /// cfg(test): derive every billing sample from the brute-force scan
    /// instead of the running aggregates (differential cost oracle).
    #[cfg(test)]
    pub(super) fn set_bill_oracle(&mut self) {
        self.bill.via_oracle = true;
    }

    /// The single choke point: re-derive one GPU's class + quantized
    /// footprint and fold the delta into the class sums. O(log G).
    /// Class *transitions* (not same-class footprint updates) fire the
    /// `on_gpu_reclass` observer hook.
    pub(super) fn reclassify_gpu(&mut self, g: GpuId) {
        self.stats.bill_reclass += 1;
        let timer = self.bill.timed.then(Instant::now);
        let Some(gpu) = self.cluster.try_gpu(g) else {
            self.bill.remove(g); // trimmed away (pre-run cluster shaping)
            return;
        };
        let used_milli = milli_gb(gpu.used_gb() - params::GPU_RESERVED_GB);
        let total_milli = milli_gb(gpu.total_gb);
        let class = classify(
            used_milli,
            self.execs[&g].is_active(),
            self.gpu_loading[&g] > 0,
            self.bill.warm_here(g),
        );
        let old = self.bill.set(g, GpuBillState { class, used_milli, total_milli });
        if let Some(timer) = timer {
            self.stats.bill_reclass_wall_s += timer.elapsed().as_secs_f64();
        }
        let from = old.map(|s| s.class);
        if from != Some(class) {
            self.emit_gpu_reclass(g, from, class);
        }
    }

    /// Snapshot of every GPU's current billing class, in GPU order
    /// (observer attach-time replay).
    pub(super) fn bill_classes(&self) -> Vec<(GpuId, BillClass)> {
        self.bill.state.iter().map(|(&g, s)| (g, s.class)).collect()
    }

    /// Classify every GPU from scratch (post-deploy initialisation).
    pub(super) fn init_billing(&mut self) {
        let _ = self.cluster.take_bill_dirty(); // deploy-time staging marks
        for g in self.cluster.gpu_ids() {
            self.reclassify_gpu(g);
        }
    }

    /// End-of-event drain: reclassify exactly the GPUs whose memory
    /// ledger changed during this event (deduplicated), refreshing their
    /// warm counts from the cluster's per-GPU residency snapshot. Work
    /// is O(GPUs touched by the event), never O(G) — and allocation-free
    /// (the dirty list and the scratch buffer swap capacities).
    pub(super) fn drain_billing_dirty(&mut self) {
        let mut dirty = std::mem::take(&mut self.bill.scratch);
        self.cluster.swap_bill_dirty(&mut dirty);
        if !dirty.is_empty() {
            dirty.sort_unstable();
            dirty.dedup();
            for &g in &dirty {
                self.refresh_warm_count(g);
                self.reclassify_gpu(g);
            }
            dirty.clear();
        }
        self.bill.scratch = dirty;
    }

    /// Recompute one GPU's warm-resident count from the residency
    /// snapshot ∩ the warm set (memory changes can add or remove a warm
    /// function's residency without a keep-alive transition).
    fn refresh_warm_count(&mut self, g: GpuId) {
        let warm_fns = &self.bill.warm_fns;
        let mut n = 0usize;
        self.cluster.for_each_resident(g, |f| {
            if warm_fns.contains(&f) {
                n += 1;
            }
        });
        if n > 0 {
            self.bill.warm_on.insert(g, n);
        } else {
            self.bill.warm_on.remove(&g);
        }
    }

    /// A function entered its keep-alive window: bump the warm count on
    /// every GPU it resides on. O(residency of f), not O(G). Fires the
    /// `on_keepalive(warm = true)` observer hook on real entries only
    /// (window extensions are silent).
    pub(super) fn note_function_warm(&mut self, f: usize) {
        if !self.bill.warm_fns.insert(f) {
            return; // already warm — the window only moved
        }
        for g in self.cluster.gpus_with_function(f) {
            *self.bill.warm_on.entry(g).or_insert(0) += 1;
            self.reclassify_gpu(g);
        }
        self.emit_keepalive(f, true);
    }

    /// A function's keep-alive window was swept: drop its warm counts.
    /// Called *before* any eviction, so the residency set still names
    /// the GPUs that were counting it (retained/agent-staged functions
    /// keep their artifacts but stop billing idle time here). Returns
    /// the residency snapshot so the caller (the keep-alive sweep) can
    /// reuse it for eviction instead of re-querying the index.
    pub(super) fn note_function_cold(&mut self, f: usize) -> Vec<GpuId> {
        let gpus = self.cluster.gpus_with_function(f);
        let was_warm = self.bill.warm_fns.remove(&f);
        if was_warm {
            for &g in &gpus {
                // A residency change earlier in the same event can
                // leave this count pending its drain refresh (the GPU
                // is bill-dirty then): adjust only what was counted —
                // the end-of-event drain recomputes every dirty GPU
                // before the next sample, and `check_billing` verifies
                // the result.
                if let Some(n) = self.bill.warm_on.get_mut(&g) {
                    *n -= 1;
                    if *n == 0 {
                        self.bill.warm_on.remove(&g);
                    }
                }
                self.reclassify_gpu(g);
            }
            self.emit_keepalive(f, false);
        }
        gpus
    }

    /// Brute-force rebuild of the whole billing classification: per-GPU
    /// states, class sums, per-GPU warm counts, per-GPU loading counts.
    /// The differential oracle for `check_billing` and the cfg(test)
    /// sample mode — this is the historical O(G) scan, kept off the hot
    /// path.
    #[allow(clippy::type_complexity)]
    fn brute_bill(
        &self,
    ) -> (
        BTreeMap<GpuId, GpuBillState>,
        [ClassSums; N_CLASSES],
        BTreeMap<GpuId, usize>,
        BTreeMap<GpuId, usize>,
    ) {
        let mut loading: BTreeMap<GpuId, usize> = BTreeMap::new();
        for b in self.batches.values() {
            if b.state == BatchState::Loading {
                *loading.entry(b.gpu).or_insert(0) += 1;
            }
        }
        let warm_fns: BTreeSet<usize> = self.keepalive.tracked().collect();
        let mut state = BTreeMap::new();
        let mut sums = [ClassSums::default(); N_CLASSES];
        let mut warm_on = BTreeMap::new();
        for g in self.cluster.gpu_ids() {
            let gpu = self.cluster.gpu(g);
            let used_milli = milli_gb(gpu.used_gb() - params::GPU_RESERVED_GB);
            let total_milli = milli_gb(gpu.total_gb);
            let warm = gpu
                .resident_functions()
                .into_iter()
                .filter(|f| warm_fns.contains(f))
                .count();
            if warm > 0 {
                warm_on.insert(g, warm);
            }
            let class = classify(
                used_milli,
                self.execs[&g].is_active(),
                loading.get(&g).copied().unwrap_or(0) > 0,
                warm > 0,
            );
            let s = GpuBillState { class, used_milli, total_milli };
            sums[class as usize].add(s);
            state.insert(g, s);
        }
        (state, sums, warm_on, loading)
    }

    /// Assert the delta-maintained aggregates equal their brute-force
    /// rebuild exactly (classes, integer milli-GB sums, warm counts,
    /// loading counts, and the warm-set mirror). Called from
    /// `Engine::check_indexes`; never by the simulation.
    pub(super) fn check_billing(&self) {
        let (state, sums, warm_on, loading) = self.brute_bill();
        let tracked: BTreeSet<usize> = self.keepalive.tracked().collect();
        assert_eq!(
            self.bill.warm_fns, tracked,
            "warm-set mirror diverged from keep-alive windows"
        );
        assert_eq!(self.bill.state, state, "per-GPU billing classification drifted");
        assert_eq!(self.bill.sums, sums, "billing class sums drifted");
        assert_eq!(self.bill.warm_on, warm_on, "per-GPU warm counts drifted");
        for (&g, &n) in &self.gpu_loading {
            let brute = loading.get(&g).copied().unwrap_or(0);
            assert_eq!(n, brute, "gpu_loading[{g}] drifted");
        }
        assert_eq!(
            self.gpu_loading.len(),
            self.cluster.n_gpus(),
            "gpu_loading must cover every GPU"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{FunctionSpec, ModelProfile};
    use crate::cluster::Cluster;
    use crate::sim::config::SystemConfig;
    use crate::sim::engine::Workload;
    use crate::trace::{Pattern, Request, TraceSpec};

    fn workload(n_fns: usize, rate: f64, dur: f64, pattern: Pattern, seed: u64) -> Workload {
        let functions: Vec<FunctionSpec> = (0..n_fns)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let traces: Vec<Vec<Request>> = (0..n_fns)
            .map(|i| TraceSpec::new(i, pattern, rate, seed + i as u64).generate(dur))
            .collect();
        Workload {
            functions,
            requests: crate::trace::merge(traces),
            duration_s: dur,
            rates: vec![rate; n_fns],
        }
    }

    #[test]
    fn quantizer_rounds_and_absorbs_ledger_noise() {
        assert_eq!(milli_gb(20.123456), 20123);
        assert_eq!(milli_gb(0.0), 0);
        assert_eq!(milli_gb(1e-9), 0);
        assert_eq!(milli_gb(-1e-9), 0);
        assert_eq!(milli_gb(48.0), 48000);
    }

    #[test]
    fn classify_precedence() {
        // Empty beats everything (nothing billable); exec beats loading
        // beats warm beats cold.
        assert_eq!(classify(0, true, true, true), BillClass::Empty);
        assert_eq!(classify(1, true, true, true), BillClass::ActiveExec);
        assert_eq!(classify(1, false, true, true), BillClass::ActiveLoading);
        assert_eq!(classify(1, false, false, true), BillClass::IdleWarm);
        assert_eq!(classify(1, false, false, false), BillClass::IdleCold);
    }

    /// The headline differential: the aggregate path and the brute-force
    /// per-GPU scan oracle must produce **bit-identical** cost totals on
    /// the same seed — the integer milli-GB sums make aggregation exact,
    /// not approximate.
    #[test]
    fn aggregate_billing_matches_scan_oracle_multi_seed() {
        for cfg in [
            SystemConfig::serverless_lora(),
            SystemConfig::serverless_llm(),
            SystemConfig::npl(),
        ] {
            for seed in [1u64, 7, 23] {
                let w = workload(4, 0.1, 600.0, Pattern::Bursty, 9 + seed);
                let fast = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w.clone(), seed);
                let (m1, c1, s1) = fast.run();
                let mut oracle = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
                oracle.set_bill_oracle();
                let (m2, c2, s2) = oracle.run();
                assert_eq!(m1.outcomes.len(), m2.outcomes.len());
                assert_eq!(
                    c1.total_usd().to_bits(),
                    c2.total_usd().to_bits(),
                    "{} seed {seed}: aggregate cost diverged from the scan oracle",
                    cfg.name
                );
                assert_eq!(c1.gpu_active_gb_s.to_bits(), c2.gpu_active_gb_s.to_bits());
                assert_eq!(c1.gpu_idle_gb_s.to_bits(), c2.gpu_idle_gb_s.to_bits());
                assert_eq!(s1.bill_samples, s2.bill_samples);
            }
        }
    }

    /// Keep-alive churn (short windows, bursty traffic) drives warm→cold
    /// transitions and evictions; the aggregates must track the brute
    /// force at every point of the run.
    #[test]
    fn aggregates_track_bruteforce_under_keepalive_churn() {
        let mut cfg = SystemConfig::serverless_lora();
        cfg.keepalive_s = 20.0;
        for seed in [3u64, 17] {
            let w = workload(4, 0.05, 900.0, Pattern::Bursty, seed);
            let mut e = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                if steps % 3 == 0 {
                    e.check_billing();
                }
            }
            e.check_billing();
            let (_, _, stats) = e.finish();
            assert!(
                stats.keepalive_checks > 3,
                "window too long to exercise expiry churn: {}",
                stats.keepalive_checks
            );
        }
    }

    /// O(1)-per-event regression: billing takes exactly one aggregate
    /// sample per positive-width interval — the sample count is bounded
    /// by the event count and does **not** scale with GPU count.
    #[test]
    fn bill_samples_are_o1_per_event_and_gpu_count_independent() {
        let run = |gpus: usize| {
            let w = workload(8, 0.1, 900.0, Pattern::Normal, 5);
            let c = Cluster::new(1, gpus, 2 * gpus);
            let (_, _, stats) = Engine::new(SystemConfig::serverless_lora(), c, w, 1).run();
            stats
        };
        let small = run(4);
        let big = run(32);
        for s in [&small, &big] {
            assert!(s.bill_samples > 0);
            assert!(
                s.bill_samples <= s.events_processed + 1,
                "{} samples for {} events — billing is not O(1)/event",
                s.bill_samples,
                s.events_processed
            );
        }
        // 8× the GPUs must not inflate billing work per event: samples
        // track events (dispatch dynamics shift slightly), not G.
        assert!(
            (big.bill_samples as f64) < 3.0 * small.bill_samples as f64,
            "bill samples scaled with GPU count: {} (4 GPUs) vs {} (32 GPUs)",
            small.bill_samples,
            big.bill_samples
        );
        assert!(
            (big.bill_reclass as f64) < 3.0 * small.bill_reclass as f64 + 64_000.0,
            "reclassifications scaled with GPU count: {} vs {}",
            small.bill_reclass,
            big.bill_reclass
        );
    }

    /// Serverful billing skips interval sampling entirely but the
    /// aggregates stay maintained (and checkable) throughout.
    #[test]
    fn serverful_takes_no_samples_but_stays_consistent() {
        let w = workload(2, 0.05, 600.0, Pattern::Predictable, 3);
        let mut e = Engine::new(SystemConfig::vllm(), Cluster::new(1, 2, 4), w, 1);
        let mut steps: u64 = 0;
        while e.step() {
            steps += 1;
            if steps % 7 == 0 {
                e.check_billing();
            }
        }
        e.check_billing();
        let (_, cost, stats) = e.finish();
        assert_eq!(stats.bill_samples, 0, "serverful must not sample intervals");
        assert!(cost.serverful_gpu_s > 0.0);
    }

    /// Billing wall-clock metering is opt-in and accumulates only when
    /// enabled — and the meter is split so sampling cost and
    /// reclassification (drain) cost are attributable separately.
    #[test]
    fn bill_timing_is_opt_in_and_split() {
        let cfg = SystemConfig::serverless_lora();
        let w = workload(2, 0.05, 300.0, Pattern::Normal, 3);
        let (_, _, off) = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w.clone(), 1).run();
        assert_eq!(off.bill_sample_wall_s, 0.0);
        assert_eq!(off.bill_reclass_wall_s, 0.0);
        let mut e = Engine::new(cfg, Cluster::new(1, 2, 4), w, 1);
        e.set_bill_timing(true);
        let (_, _, on) = e.run();
        assert!(on.bill_sample_wall_s > 0.0, "timed run recorded no sampling time");
        assert!(on.bill_reclass_wall_s > 0.0, "timed run recorded no reclass time");
    }
}
