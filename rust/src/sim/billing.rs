//! Event-integrated billing: between two events the engine samples every
//! GPU's billable state (resident GB, active vs idle, warm residents) and
//! hands the sample to the bundle's `BillingModel`. The engine never
//! decides *how* resource-time prices — serverless GB·s vs serverful flat
//! billing is entirely the policy's call.

use std::collections::BTreeSet;

use crate::artifact::params;
use crate::cluster::GpuId;
use crate::coordinator::policy::GpuBillSample;
use crate::sim::dispatch::BatchState;
use crate::sim::engine::Engine;

impl Engine {
    /// Integrate cost over `[last_bill_t, until)`.
    pub(super) fn bill_interval(&mut self, until: f64) {
        let dt = until - self.last_bill_t;
        if dt <= 0.0 || !self.policies.billing.needs_interval() {
            self.last_bill_t = until.max(self.last_bill_t);
            return;
        }
        // GPUs with an in-flight artifact load count as active: loading
        // bills like execution (the instance is allocated and working).
        let loading: BTreeSet<GpuId> = self
            .batches
            .values()
            .filter(|b| b.state == BatchState::Loading)
            .map(|b| b.gpu)
            .collect();
        for g in self.cluster.gpu_ids() {
            let gpu = self.cluster.gpu(g);
            let used = gpu.used_gb() - params::GPU_RESERVED_GB;
            let active = self.execs[&g].is_active() || loading.contains(&g);
            // Idle (keep-alive) billing applies to *user instances* kept
            // warm after an invocation. Artifacts staged by a pre-loading
            // agent in the provider's idle pool are not billed to the
            // user (§2.4: "pre-loading without extra wastage") — so idle
            // GB·s accrue only while some keep-alive-warm function
            // resides on this GPU. Only the idle, non-empty case reads
            // this flag, so skip the resident scan everywhere else (this
            // runs between every pair of events).
            let warm_resident = !active
                && used > 0.0
                && gpu
                    .resident_functions()
                    .iter()
                    .any(|&f| self.keepalive.is_warm(f, self.last_bill_t));
            let sample = GpuBillSample {
                used_gb: used,
                total_gb: gpu.total_gb,
                active,
                warm_resident,
            };
            self.policies.billing.bill_gpu(&sample, dt, &mut self.cost);
        }
        self.last_bill_t = until;
    }
}
