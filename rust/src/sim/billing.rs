//! Event-integrated billing on **delta-maintained aggregates**.
//!
//! Historically the engine sampled every GPU's billable state between
//! *every pair of events* — an O(G) walk (plus a batch scan for loading
//! GPUs and a `resident_functions()` allocation on idle ones) that became
//! the densest per-event path once the event core went O(1). Both §6.1
//! pricing rules (serverless GB·s, serverful flat) are linear within a
//! billing class, so the engine now keeps each GPU classified into one of
//! a small set of classes and maintains running per-class sums (count,
//! Σ used, Σ capacity); `bill_interval` hands the [`BillingModel`] one
//! [`AggregateBillSample`] per interval — O(1) per event regardless of
//! fleet size.
//!
//! ## Classes
//!
//! * **Empty** — no billable bytes above the runtime reserve; never
//!   billed (and never sampled).
//! * **ActiveExec** — at least one executing batch.
//! * **ActiveLoading** — an in-flight artifact load but nothing
//!   executing; bills like execution (the instance is allocated and
//!   working).
//! * **IdleWarm** — idle, hosting ≥1 keep-alive-warm function; bills
//!   idle GB·s (§2.2 keep-alive wastage).
//! * **IdleCold** — idle, residency entirely agent-staged; not billed to
//!   users (§2.4 "pre-loading without extra wastage").
//!
//! ## Maintenance
//!
//! Every state change funnels through the [`Engine::reclassify_gpu`]
//! choke point, O(1) per call:
//!
//! * **memory deltas** (`load_artifact`/`evict`/KV/context/shared
//!   segments, including policy-internal mutations) mark the GPU in the
//!   cluster's `bill_dirty` channel via `gpu_mut`; the engine drains it
//!   once at the end of each event;
//! * **exec start/finish** reclassify from `schedule_tick` (called after
//!   every exec mutation);
//! * **batch Loading→Prefill transitions** maintain the per-GPU
//!   `gpu_loading` count and reclassify at both ends;
//! * **keep-alive warm/cold transitions** adjust the per-GPU warm-count
//!   aggregate over the function's resident GPUs.
//!
//! The idle-GPU warm test reads the per-GPU warm-count arena, which is
//! maintained as a proper two-key index: `warm_pairs` holds exactly the
//! (dense gpu, function) pairs that are warm *and* resident, fed by the
//! GPUs' residency-flip journals (`Gpu::res_log`) at drain time and by
//! the keep-alive transitions. Both feeds mutate the pair set
//! idempotently, so a residency flip and a warm transition landing in
//! the same event cannot double-count; journal `(f, false)` entries
//! remove the pair *unconditionally* (not gated on the current warm
//! set), because an evict-then-cold sequence within one event shrinks
//! the cold snapshot before the journal drains. Per-GPU state lives in
//! dense arenas indexed by the engine's `GpuDenseMap` — no
//! `resident_functions()` snapshot walk, no per-GPU BTreeMap chasing.
//!
//! ## Exactness
//!
//! Σ used is tracked in integer **milli-GB** (quantized once per GPU per
//! reclassification, converted to GB at the sample boundary): integer
//! deltas cannot drift, so the running sums stay bit-identical to a
//! brute-force rebuild over the whole run — `Engine::check_billing`
//! asserts exactly that, and a cfg(test) oracle mode re-derives every
//! sample by full scan for the differential cost tests.

use std::collections::BTreeSet;
use std::time::Instant;

use crate::artifact::params;
use crate::cluster::GpuId;
use crate::coordinator::policy::{AggregateBillSample, ClassBillSample};
use crate::sim::dispatch::BatchState;
use crate::sim::engine::Engine;
use crate::sim::observe::Observer;

/// Quantize GB to integer milli-GB (the aggregate's fixed-point unit).
/// Sub-milli-GB residue (f64 ledger noise) rounds to zero instead of
/// accumulating in the running sums.
fn milli_gb(gb: f64) -> i64 {
    (gb * 1000.0).round() as i64
}

/// The billing classes (see module docs). Discriminants index
/// [`BillingIndex::sums`]. Public: observer hooks
/// (`sim::observe::Observer::on_gpu_reclass`) report class transitions.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BillClass {
    Empty = 0,
    ActiveExec = 1,
    ActiveLoading = 2,
    IdleWarm = 3,
    IdleCold = 4,
}

const N_CLASSES: usize = 5;

fn classify(used_milli: i64, executing: bool, loading: bool, warm: bool) -> BillClass {
    if used_milli <= 0 {
        BillClass::Empty
    } else if executing {
        BillClass::ActiveExec
    } else if loading {
        BillClass::ActiveLoading
    } else if warm {
        BillClass::IdleWarm
    } else {
        BillClass::IdleCold
    }
}

/// One GPU's current contribution to the class sums.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct GpuBillState {
    pub(super) class: BillClass,
    pub(super) used_milli: i64,
    pub(super) total_milli: i64,
}

/// Running totals for one class.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub(super) struct ClassSums {
    pub(super) count: usize,
    pub(super) used_milli: i64,
    pub(super) total_milli: i64,
}

impl ClassSums {
    fn add(&mut self, s: GpuBillState) {
        self.count += 1;
        self.used_milli += s.used_milli;
        self.total_milli += s.total_milli;
    }

    fn sub(&mut self, s: GpuBillState) {
        self.count -= 1;
        self.used_milli -= s.used_milli;
        self.total_milli -= s.total_milli;
    }
}

/// The engine's billing aggregates: per-GPU classification mirror, the
/// per-class running sums, and the keep-alive warm-set bookkeeping.
#[derive(Debug, Default)]
pub(super) struct BillingIndex {
    /// Dense GPU index → its counted class + quantized footprint
    /// (`None` only before `init_billing`).
    state: Vec<Option<GpuBillState>>,
    /// Per-class (count, Σ used milli-GB, Σ capacity milli-GB).
    sums: [ClassSums; N_CLASSES],
    /// Mirror of the keep-alive window set (`KeepAlive::contains`):
    /// inserted on touch, removed when the sweep pops the window.
    warm_fns: BTreeSet<usize>,
    /// Two-key warm-residency index: exactly the (dense gpu, function)
    /// pairs with `function` warm and resident on `gpu` (between
    /// events; mid-event transients are reconciled by the drain). Both
    /// maintenance feeds — keep-alive transitions and the residency-flip
    /// journals — insert/remove idempotently, and `warm_on` moves only
    /// on actual set mutations.
    warm_pairs: BTreeSet<(usize, usize)>,
    /// Dense GPU index → number of warm functions resident there (the
    /// materialized per-GPU count of `warm_pairs`). The idle-warm class
    /// test is an O(1) arena read.
    warm_on: Vec<u32>,
    /// Reused drain buffer (swapped with the cluster's `bill_dirty`
    /// channel each event, so neither side re-allocates on the hot
    /// path).
    scratch: Vec<GpuId>,
    /// Reused residency-flip buffer (swapped with each dirty GPU's
    /// journal at drain time).
    log_buf: Vec<(usize, bool)>,
    /// Measure the split billing wall-clock meters (fleet bench only —
    /// `Instant` calls are not free at millions of events per second).
    timed: bool,
    /// cfg(test): derive every sample from a brute-force scan instead of
    /// the running sums (the differential cost oracle).
    #[cfg(test)]
    pub(super) via_oracle: bool,
}

impl BillingIndex {
    /// Install one GPU's state, folding the delta into the class sums.
    /// Returns the displaced state so the caller can report class
    /// *transitions* to observers.
    fn set(&mut self, d: usize, new: GpuBillState) -> Option<GpuBillState> {
        let old = self.state[d].replace(new);
        if let Some(old) = old {
            self.sums[old.class as usize].sub(old);
        }
        self.sums[new.class as usize].add(new);
        old
    }

    fn warm_here(&self, d: usize) -> bool {
        self.warm_on[d] > 0
    }

    fn sample(sums: &[ClassSums; N_CLASSES]) -> AggregateBillSample {
        let class = |c: BillClass| {
            let s = &sums[c as usize];
            ClassBillSample {
                gpus: s.count,
                used_gb: s.used_milli as f64 / 1000.0,
                total_gb: s.total_milli as f64 / 1000.0,
            }
        };
        AggregateBillSample {
            active: class(BillClass::ActiveExec),
            loading: class(BillClass::ActiveLoading),
            idle_warm: class(BillClass::IdleWarm),
            idle_cold: class(BillClass::IdleCold),
        }
    }
}

impl Engine {
    /// Integrate cost over `[last_bill_t, until)`: one aggregate sample,
    /// priced by the built-in [`BilledCost`] observer — no per-GPU work.
    /// The same sample then fans out to the opt-in series sampler and
    /// any attached observers (after the built-in, so extras can never
    /// perturb the money path).
    ///
    /// [`BilledCost`]: crate::sim::observe::BilledCost
    pub(super) fn bill_interval(&mut self, until: f64) {
        let dt = until - self.last_bill_t;
        if dt <= 0.0 || !self.cost_obs.model.needs_interval() {
            self.last_bill_t = until.max(self.last_bill_t);
            return;
        }
        let t0 = self.last_bill_t;
        let timer = self.bill.timed.then(Instant::now);
        let sample = self.bill_sample();
        Observer::on_bill_sample(&mut self.cost_obs, t0, dt, &sample);
        self.stats.bill_samples += 1;
        self.last_bill_t = until;
        // Snapshot-storage surcharge (cold-start subsystem): resident
        // snapshot GB × interval × the policy's storage rate, directly in
        // dollars (no rate class — snapshots live in host RAM the cache
        // already owns). The guard keeps every snapshot-free run — and
        // the historical goldens — float-op free here.
        if self.snap_gb_total > 0.0 {
            let rate = self.cold_start.snapshot().storage_usd_per_gb_h;
            self.cost_obs.cost.snapshot_usd += self.snap_gb_total * dt / 3600.0 * rate;
        }
        if let Some(s) = self.series.as_mut() {
            s.on_bill_sample(t0, dt, &sample);
        }
        for ob in &mut self.observers {
            ob.on_bill_sample(t0, dt, &sample);
        }
        // The meter covers the whole per-sample path: production,
        // pricing, and the fan-out to the series sampler / attached
        // observers — so enabling a sink shows up in the trajectory.
        if let Some(timer) = timer {
            self.stats.bill_sample_wall_s += timer.elapsed().as_secs_f64();
        }
    }

    fn bill_sample(&self) -> AggregateBillSample {
        #[cfg(test)]
        if self.bill.via_oracle {
            let (_, sums, _, _, _) = self.brute_bill();
            return BillingIndex::sample(&sums);
        }
        BillingIndex::sample(&self.bill.sums)
    }

    /// Measure billing wall-clock into the split meters
    /// (`RunStats::bill_sample_wall_s` for sampling + pricing,
    /// `bill_reclass_wall_s` for class maintenance) — the fleet bench's
    /// "billing share" record. Off by default.
    pub fn set_bill_timing(&mut self, on: bool) {
        self.bill.timed = on;
    }

    /// cfg(test): derive every billing sample from the brute-force scan
    /// instead of the running aggregates (differential cost oracle).
    #[cfg(test)]
    pub(super) fn set_bill_oracle(&mut self) {
        self.bill.via_oracle = true;
    }

    /// The single choke point: re-derive one GPU's class + quantized
    /// footprint and fold the delta into the class sums. O(1) arena
    /// reads. Class *transitions* (not same-class footprint updates)
    /// fire the `on_gpu_reclass` observer hook.
    pub(super) fn reclassify_gpu(&mut self, g: GpuId) {
        self.stats.bill_reclass += 1;
        let timer = self.bill.timed.then(Instant::now);
        // Pre-run cluster shaping (`trim_gpus`) can leave marks for ids
        // that no longer exist — whose dense translation would alias a
        // live slot of a later node. `try_gpu` success is exactly dense
        // validity; GPUs never disappear mid-run, so a stale id is
        // simply skipped (init_billing discards the pre-run marks).
        let Some(gpu) = self.cluster.try_gpu(g) else {
            return;
        };
        let used_milli = milli_gb(gpu.used_gb() - params::GPU_RESERVED_GB);
        let total_milli = milli_gb(gpu.total_gb);
        let d = self.gpu_map.dense(g);
        let class = classify(
            used_milli,
            self.execs[d].is_active(),
            self.gpu_loading[d] > 0,
            self.bill.warm_here(d),
        );
        let old = self.bill.set(d, GpuBillState { class, used_milli, total_milli });
        if let Some(timer) = timer {
            self.stats.bill_reclass_wall_s += timer.elapsed().as_secs_f64();
        }
        let from = old.map(|s| s.class);
        if from != Some(class) {
            self.emit_gpu_reclass(g, from, class);
        }
    }

    /// Snapshot of every GPU's current billing class, in GPU order
    /// (observer attach-time replay; dense order == `GpuId` order).
    pub(super) fn bill_classes(&self) -> Vec<(GpuId, BillClass)> {
        self.bill
            .state
            .iter()
            .enumerate()
            .filter_map(|(d, s)| s.map(|s| (self.gpu_map.id(d), s.class)))
            .collect()
    }

    /// Classify every GPU from scratch (post-deploy initialisation).
    /// Sizes the dense arenas and discards deploy-time dirty marks and
    /// residency flips — nothing was warm before t=0, so pre-run
    /// staging contributes no warm pairs.
    pub(super) fn init_billing(&mut self) {
        let n = self.gpu_map.len();
        self.bill.state = vec![None; n];
        self.bill.warm_on = vec![0; n];
        self.bill.warm_pairs.clear();
        self.bill.sums = Default::default();
        let _ = self.cluster.take_bill_dirty(); // deploy-time staging marks
        self.cluster.clear_res_logs();
        for g in self.cluster.gpu_ids() {
            self.reclassify_gpu(g);
        }
    }

    /// End-of-event drain: for exactly the GPUs whose memory ledger
    /// changed during this event (deduplicated), apply their
    /// residency-flip journals to the two-key warm index, then
    /// reclassify. Work is O(GPUs touched × flips), never O(G) or
    /// O(resident functions) — and allocation-free (dirty list, scratch
    /// buffer, and flip buffer all swap capacities).
    pub(super) fn drain_billing_dirty(&mut self) {
        let mut dirty = std::mem::take(&mut self.bill.scratch);
        self.cluster.swap_bill_dirty(&mut dirty);
        if !dirty.is_empty() {
            dirty.sort_unstable();
            dirty.dedup();
            let mut log = std::mem::take(&mut self.bill.log_buf);
            for &g in &dirty {
                if self.cluster.try_gpu(g).is_none() {
                    continue; // trimmed pre-run; dense would alias
                }
                let d = self.gpu_map.dense(g);
                self.cluster.take_res_log(g, &mut log);
                for &(f, on) in &log {
                    if on {
                        // Gated on the *current* warm set; idempotent
                        // against a same-event `note_function_warm`.
                        if self.bill.warm_fns.contains(&f)
                            && self.bill.warm_pairs.insert((d, f))
                        {
                            self.bill.warm_on[d] += 1;
                        }
                    } else if self.bill.warm_pairs.remove(&(d, f)) {
                        // NOT gated on the warm set: an evict-then-cold
                        // sequence within one event removes `g` from the
                        // cold transition's residency snapshot, so this
                        // journal entry is the only thing left that can
                        // clear the pair.
                        self.bill.warm_on[d] -= 1;
                    }
                }
                self.reclassify_gpu(g);
            }
            log.clear();
            self.bill.log_buf = log;
            dirty.clear();
        }
        self.bill.scratch = dirty;
    }

    /// A function entered its keep-alive window: bump the warm count on
    /// every GPU it resides on. O(residency of f), not O(G). Fires the
    /// `on_keepalive(warm = true)` observer hook on real entries only
    /// (window extensions are silent).
    pub(super) fn note_function_warm(&mut self, f: usize) {
        if !self.bill.warm_fns.insert(f) {
            return; // already warm — the window only moved
        }
        for g in self.cluster.gpus_with_function(f) {
            let d = self.gpu_map.dense(g);
            // Idempotent against a pending `(f, true)` residency flip
            // from earlier in this event: whichever feed lands second
            // finds the pair present and leaves the count alone.
            if self.bill.warm_pairs.insert((d, f)) {
                self.bill.warm_on[d] += 1;
            }
            self.reclassify_gpu(g);
        }
        self.emit_keepalive(f, true);
    }

    /// A function's keep-alive window was swept: drop its warm counts.
    /// Called *before* any eviction, so the residency set still names
    /// the GPUs that were counting it (retained/agent-staged functions
    /// keep their artifacts but stop billing idle time here). Returns
    /// the residency snapshot so the caller (the keep-alive sweep) can
    /// reuse it for eviction instead of re-querying the index.
    pub(super) fn note_function_cold(&mut self, f: usize) -> Vec<GpuId> {
        let gpus = self.cluster.gpus_with_function(f);
        let was_warm = self.bill.warm_fns.remove(&f);
        if was_warm {
            for &g in &gpus {
                let d = self.gpu_map.dense(g);
                // Idempotent removal: only pairs actually counted move
                // the count. GPUs this function left earlier in the
                // same event are outside `gpus` by now — their pending
                // `(f, false)` journal entries clear those pairs at the
                // end-of-event drain.
                if self.bill.warm_pairs.remove(&(d, f)) {
                    self.bill.warm_on[d] -= 1;
                }
                self.reclassify_gpu(g);
            }
            self.emit_keepalive(f, false);
        }
        gpus
    }

    /// Brute-force rebuild of the whole billing classification: per-GPU
    /// states, class sums, per-GPU warm counts, per-GPU loading counts.
    /// The differential oracle for `check_billing` and the cfg(test)
    /// sample mode — this is the historical O(G) scan, kept off the hot
    /// path.
    #[allow(clippy::type_complexity)]
    fn brute_bill(
        &self,
    ) -> (
        Vec<Option<GpuBillState>>,
        [ClassSums; N_CLASSES],
        Vec<u32>,
        Vec<usize>,
        BTreeSet<(usize, usize)>,
    ) {
        let n = self.gpu_map.len();
        let mut loading = vec![0usize; n];
        for b in self.batches.values() {
            if b.state == BatchState::Loading {
                loading[self.gpu_map.dense(b.gpu)] += 1;
            }
        }
        let warm_fns: BTreeSet<usize> = self.keepalive.tracked().collect();
        let mut state = vec![None; n];
        let mut sums = [ClassSums::default(); N_CLASSES];
        let mut warm_on = vec![0u32; n];
        let mut warm_pairs = BTreeSet::new();
        for (d, &g) in self.gpu_map.ids().iter().enumerate() {
            let gpu = self.cluster.gpu(g);
            let used_milli = milli_gb(gpu.used_gb() - params::GPU_RESERVED_GB);
            let total_milli = milli_gb(gpu.total_gb);
            for f in gpu.resident_functions() {
                if warm_fns.contains(&f) {
                    warm_pairs.insert((d, f));
                    warm_on[d] += 1;
                }
            }
            let class = classify(
                used_milli,
                self.execs[d].is_active(),
                loading[d] > 0,
                warm_on[d] > 0,
            );
            let s = GpuBillState { class, used_milli, total_milli };
            sums[class as usize].add(s);
            state[d] = Some(s);
        }
        (state, sums, warm_on, loading, warm_pairs)
    }

    /// Assert the delta-maintained aggregates equal their brute-force
    /// rebuild exactly (classes, integer milli-GB sums, the two-key
    /// warm-pair index and its per-GPU counts, loading counts, and the
    /// warm-set mirror). Called from `Engine::check_indexes`; never by
    /// the simulation.
    pub(super) fn check_billing(&self) {
        let (state, sums, warm_on, loading, warm_pairs) = self.brute_bill();
        let tracked: BTreeSet<usize> = self.keepalive.tracked().collect();
        assert_eq!(
            self.bill.warm_fns, tracked,
            "warm-set mirror diverged from keep-alive windows"
        );
        assert_eq!(self.bill.state, state, "per-GPU billing classification drifted");
        assert_eq!(self.bill.sums, sums, "billing class sums drifted");
        assert_eq!(self.bill.warm_pairs, warm_pairs, "warm-pair index drifted");
        assert_eq!(self.bill.warm_on, warm_on, "per-GPU warm counts drifted");
        assert_eq!(self.gpu_loading, loading, "gpu_loading drifted");
        assert_eq!(
            self.gpu_loading.len(),
            self.cluster.n_gpus(),
            "gpu_loading must cover every GPU"
        );
        // Checks run between events: every residency-flip journal must
        // have been drained into the pair index by then.
        for g in self.cluster.gpus() {
            assert!(
                g.res_log().is_empty(),
                "undrained residency flips on {}",
                g.id
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{FunctionSpec, ModelProfile};
    use crate::cluster::Cluster;
    use crate::sim::config::SystemConfig;
    use crate::sim::engine::Workload;
    use crate::trace::{Pattern, Request, TraceSpec};

    fn workload(n_fns: usize, rate: f64, dur: f64, pattern: Pattern, seed: u64) -> Workload {
        let functions: Vec<FunctionSpec> = (0..n_fns)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let traces: Vec<Vec<Request>> = (0..n_fns)
            .map(|i| TraceSpec::new(i, pattern, rate, seed + i as u64).generate(dur))
            .collect();
        Workload {
            functions,
            requests: crate::trace::merge(traces),
            duration_s: dur,
            rates: vec![rate; n_fns],
        }
    }

    #[test]
    fn quantizer_rounds_and_absorbs_ledger_noise() {
        assert_eq!(milli_gb(20.123456), 20123);
        assert_eq!(milli_gb(0.0), 0);
        assert_eq!(milli_gb(1e-9), 0);
        assert_eq!(milli_gb(-1e-9), 0);
        assert_eq!(milli_gb(48.0), 48000);
    }

    #[test]
    fn classify_precedence() {
        // Empty beats everything (nothing billable); exec beats loading
        // beats warm beats cold.
        assert_eq!(classify(0, true, true, true), BillClass::Empty);
        assert_eq!(classify(1, true, true, true), BillClass::ActiveExec);
        assert_eq!(classify(1, false, true, true), BillClass::ActiveLoading);
        assert_eq!(classify(1, false, false, true), BillClass::IdleWarm);
        assert_eq!(classify(1, false, false, false), BillClass::IdleCold);
    }

    /// The headline differential: the aggregate path and the brute-force
    /// per-GPU scan oracle must produce **bit-identical** cost totals on
    /// the same seed — the integer milli-GB sums make aggregation exact,
    /// not approximate.
    #[test]
    fn aggregate_billing_matches_scan_oracle_multi_seed() {
        for cfg in [
            SystemConfig::serverless_lora(),
            SystemConfig::serverless_llm(),
            SystemConfig::npl(),
        ] {
            for seed in [1u64, 7, 23] {
                let w = workload(4, 0.1, 600.0, Pattern::Bursty, 9 + seed);
                let fast = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w.clone(), seed);
                let (m1, c1, s1) = fast.run();
                let mut oracle = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
                oracle.set_bill_oracle();
                let (m2, c2, s2) = oracle.run();
                assert_eq!(m1.outcomes.len(), m2.outcomes.len());
                assert_eq!(
                    c1.total_usd().to_bits(),
                    c2.total_usd().to_bits(),
                    "{} seed {seed}: aggregate cost diverged from the scan oracle",
                    cfg.name
                );
                assert_eq!(c1.gpu_active_gb_s.to_bits(), c2.gpu_active_gb_s.to_bits());
                assert_eq!(c1.gpu_idle_gb_s.to_bits(), c2.gpu_idle_gb_s.to_bits());
                assert_eq!(s1.bill_samples, s2.bill_samples);
            }
        }
    }

    /// Keep-alive churn (short windows, bursty traffic) drives warm→cold
    /// transitions and evictions; the aggregates must track the brute
    /// force at every point of the run.
    #[test]
    fn aggregates_track_bruteforce_under_keepalive_churn() {
        let mut cfg = SystemConfig::serverless_lora();
        cfg.keepalive_s = 20.0;
        for seed in [3u64, 17] {
            let w = workload(4, 0.05, 900.0, Pattern::Bursty, seed);
            let mut e = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                if steps % 3 == 0 {
                    e.check_billing();
                }
            }
            e.check_billing();
            let (_, _, stats) = e.finish();
            assert!(
                stats.keepalive_checks > 3,
                "window too long to exercise expiry churn: {}",
                stats.keepalive_checks
            );
        }
    }

    /// O(1)-per-event regression: billing takes exactly one aggregate
    /// sample per positive-width interval — the sample count is bounded
    /// by the event count and does **not** scale with GPU count.
    #[test]
    fn bill_samples_are_o1_per_event_and_gpu_count_independent() {
        let run = |gpus: usize| {
            let w = workload(8, 0.1, 900.0, Pattern::Normal, 5);
            let c = Cluster::new(1, gpus, 2 * gpus);
            let (_, _, stats) = Engine::new(SystemConfig::serverless_lora(), c, w, 1).run();
            stats
        };
        let small = run(4);
        let big = run(32);
        for s in [&small, &big] {
            assert!(s.bill_samples > 0);
            assert!(
                s.bill_samples <= s.events_processed + 1,
                "{} samples for {} events — billing is not O(1)/event",
                s.bill_samples,
                s.events_processed
            );
        }
        // 8× the GPUs must not inflate billing work per event: samples
        // track events (dispatch dynamics shift slightly), not G.
        assert!(
            (big.bill_samples as f64) < 3.0 * small.bill_samples as f64,
            "bill samples scaled with GPU count: {} (4 GPUs) vs {} (32 GPUs)",
            small.bill_samples,
            big.bill_samples
        );
        assert!(
            (big.bill_reclass as f64) < 3.0 * small.bill_reclass as f64 + 64_000.0,
            "reclassifications scaled with GPU count: {} vs {}",
            small.bill_reclass,
            big.bill_reclass
        );
    }

    /// Serverful billing skips interval sampling entirely but the
    /// aggregates stay maintained (and checkable) throughout.
    #[test]
    fn serverful_takes_no_samples_but_stays_consistent() {
        let w = workload(2, 0.05, 600.0, Pattern::Predictable, 3);
        let mut e = Engine::new(SystemConfig::vllm(), Cluster::new(1, 2, 4), w, 1);
        let mut steps: u64 = 0;
        while e.step() {
            steps += 1;
            if steps % 7 == 0 {
                e.check_billing();
            }
        }
        e.check_billing();
        let (_, cost, stats) = e.finish();
        assert_eq!(stats.bill_samples, 0, "serverful must not sample intervals");
        assert!(cost.serverful_gpu_s > 0.0);
    }

    /// Billing wall-clock metering is opt-in and accumulates only when
    /// enabled — and the meter is split so sampling cost and
    /// reclassification (drain) cost are attributable separately.
    #[test]
    fn bill_timing_is_opt_in_and_split() {
        let cfg = SystemConfig::serverless_lora();
        let w = workload(2, 0.05, 300.0, Pattern::Normal, 3);
        let (_, _, off) = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w.clone(), 1).run();
        assert_eq!(off.bill_sample_wall_s, 0.0);
        assert_eq!(off.bill_reclass_wall_s, 0.0);
        let mut e = Engine::new(cfg, Cluster::new(1, 2, 4), w, 1);
        e.set_bill_timing(true);
        let (_, _, on) = e.run();
        assert!(on.bill_sample_wall_s > 0.0, "timed run recorded no sampling time");
        assert!(on.bill_reclass_wall_s > 0.0, "timed run recorded no reclass time");
    }
}
