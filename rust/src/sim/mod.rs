//! Discrete-event simulation of the serving systems: engine, GPU
//! processor-sharing executor (Eq. 4), and the system/baseline configs.

pub mod config;
pub mod engine;
pub mod exec;
pub mod workloads;

pub use config::{BatchingMode, PreloadMode, SystemConfig};
pub use engine::{Engine, RunStats, Workload};
pub use exec::GpuExec;
