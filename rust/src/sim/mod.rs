//! Discrete-event simulation of the serving systems, decomposed into an
//! orchestrating `engine`, the `events` queue, the batch-lifecycle
//! `dispatch` path, event-integrated `billing`, the GPU processor-sharing
//! executor (Eq. 4) in `exec`, and the system/baseline `config`s that
//! build the policy bundles driving it all (see DESIGN.md §3).

pub mod billing;
pub mod config;
pub mod dispatch;
pub mod engine;
pub mod events;
pub mod exec;
pub mod workloads;

pub use config::{BatchingMode, PreloadMode, SystemConfig};
pub use engine::{Engine, RunStats, Workload};
pub use events::{Event, EventKind, EventQueue, EventToken};
pub use exec::GpuExec;
