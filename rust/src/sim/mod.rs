//! Discrete-event simulation of the serving systems, decomposed into an
//! orchestrating `engine`, the `events` queue, the batch-lifecycle
//! `dispatch` path, event-integrated `billing`, the GPU processor-sharing
//! executor (Eq. 4) in `exec`, the `observe` output surface (the
//! `Observer` hook contract the engine emits its results through), and
//! the system/baseline `config`s that build the policy bundles driving
//! it all (see DESIGN.md §3 and §"Scenario API & observers").

pub mod billing;
pub mod coldstart;
pub mod config;
pub mod dispatch;
pub mod engine;
pub mod events;
pub mod exec;
pub mod fault;
pub mod flow;
pub mod observe;
pub mod sharded;
pub mod workloads;

pub use billing::BillClass;
pub use config::{BatchingMode, CacheMode, PreloadMode, SystemConfig, TierSpec};
pub use fault::{
    DegradeSpec, DomainLevel, DomainSpec, FaultEvent, FaultInjector, FaultSpec, RetrySpec,
};
pub use flow::{FlowNet, Retime};
pub use engine::{Engine, RunStats, Workload};
pub use events::{Event, EventKind, EventQueue, EventToken};
pub use exec::GpuExec;
pub use observe::{BillSeries, BillSeriesSampler, BilledCost, Observer, RunOutput, TraceExport};
