//! Discrete-event serving simulator — the thin orchestrator.
//!
//! Drives one `SystemConfig` over a trace on the simulated cluster. The
//! engine core owns *mechanism only*: the event loop (`sim::events`), the
//! batch lifecycle (`sim::dispatch`: arrival → load → prefill → decode),
//! and event-integrated billing (`sim::billing`). Every *policy* decision
//! — what is pre-staged and what a cold start costs, when a batch fires,
//! how memory pressure is resolved, how resource-time turns into dollars
//! — is routed through the `coordinator::policy` traits carried in the
//! [`PolicyBundle`] that `SystemConfig::bundle` builds. Adding a system
//! touches the config layer, never this file.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::artifact::{ArtifactKind, FunctionSpec};
use crate::cluster::{Cluster, GpuDenseMap, GpuId};
use crate::coordinator::policy::{
    BatchingPolicy, CachePolicy, ColdStartPolicy, OffloadPolicy, PolicyBundle,
    PolicyEnv, PreloadPolicy,
};
use crate::coordinator::{BatchQueue, KeepAlive};
use crate::cost::CostTracker;
use crate::metrics::{RequestOutcome, RunMetrics};
pub use crate::metrics::RunStats;
use crate::sharing::BackboneRegistry;
use crate::sim::billing::{BillClass, BillingIndex};
use crate::sim::coldstart::{PipeRun, PipeShard};
use crate::sim::config::SystemConfig;
use crate::sim::dispatch::{Batch, LoadRun};
use crate::sim::events::{EventKind, EventQueue, EventToken};
use crate::sim::fault::FaultInjector;
use crate::sim::exec::GpuExec;
use crate::sim::flow::FlowNet;
use crate::sim::observe::{BillSeriesSampler, BilledCost, Observer, RunOutput};
use crate::trace::Request;

/// The ≤2 outstanding wakeups for one function's queue (debounce settle
/// + Eq. 3 expiry). Superseded wakeups are *cancelled* outright on every
/// re-arm; a token whose event already fired is inert (its slab slot's
/// generation moved on), so stale handles left here are harmless.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub(super) struct QueueWakeups {
    pub(super) settle: Option<EventToken>,
    pub(super) expiry: Option<EventToken>,
}

impl QueueWakeups {
    pub(super) fn tokens(self) -> impl Iterator<Item = EventToken> {
        [self.settle, self.expiry].into_iter().flatten()
    }
}

/// A workload: functions + merged time-ordered request stream.
#[derive(Debug, Clone)]
pub struct Workload {
    pub functions: Vec<FunctionSpec>,
    pub requests: Vec<Request>,
    pub duration_s: f64,
    /// Mean arrival rate per function (pre-loading benefit input, §4.1).
    pub rates: Vec<f64>,
}

pub struct Engine {
    pub(super) cfg: SystemConfig,
    /// §4.1 artifact staging policy (what is resident, what a cold
    /// start costs) — from the config's [`PolicyBundle`].
    pub(super) preload: Box<dyn PreloadPolicy>,
    /// §4.2 batching policy (fire-now, sizing, prioritisation).
    pub(super) batching: Box<dyn BatchingPolicy>,
    /// §4.3 memory-pressure policy.
    pub(super) offload: Box<dyn OffloadPolicy>,
    /// §"Tiered store" checkpoint-cache admission/eviction policy (fifth
    /// trait in the bundle). Consulted only when `cfg.tiers` is set.
    pub(super) cache: Box<dyn CachePolicy>,
    pub(super) cluster: Cluster,
    pub(super) registry: BackboneRegistry,
    pub(super) keepalive: KeepAlive,
    pub(super) functions: Vec<FunctionSpec>,
    pub(super) rates: Vec<f64>,
    pub(super) queues: Vec<BatchQueue>,
    /// Dense `GpuId ↔ 0..n_gpus` translation for the arena state below.
    /// The per-GPU hot fields read on every event (exec job sets, busy /
    /// loading counts, tick tokens, billing classes) live in dense
    /// index-addressed arenas so the dispatch/billing hot loops stride
    /// contiguous memory instead of chasing `BTreeMap` nodes; dense order
    /// equals `GpuId` order, so every "iterate all GPUs" walk replays the
    /// historical map order bit-identically.
    pub(super) gpu_map: GpuDenseMap,
    /// Per-GPU processor-sharing executors (dense arena).
    pub(super) execs: Vec<GpuExec>,
    pub(super) events: EventQueue,
    pub(super) now: f64,
    pub(super) batches: BTreeMap<u64, Batch>,
    pub(super) next_batch: u64,
    /// Fair-share state of every in-flight transfer, per `(node, link)`
    /// (`sim::flow`). Empty whenever `cfg.tiers` is `None`.
    pub(super) flows: FlowNet,
    /// Segmented (tiered) loads in flight: batch id → its run cursor.
    /// Flat-path loads never appear here.
    pub(super) load_runs: BTreeMap<u64, LoadRun>,
    /// Functions blocked on GPU memory (NDO): `f → the GPU whose memory
    /// it is waiting on` (`None` = routing found no GPU at all). Retried
    /// when that GPU frees memory, instead of wholesale on every
    /// completion anywhere.
    pub(super) blocked: BTreeMap<usize, Option<GpuId>>,
    /// Dirty dispatch candidates: exactly the functions with non-empty
    /// queues. `try_dispatch_all(None)` scans this set instead of every
    /// queue (`should_dispatch` is identically false on empty queues).
    pub(super) active: BTreeSet<usize>,
    /// Incremental index: in-flight batch count per function (replaces
    /// the O(batches) `any(|b| b.function == f)` scans).
    pub(super) fn_inflight: Vec<usize>,
    /// Incremental index: per-GPU count of batches in `Loading` or
    /// `Prefill` state (dense arena; replaces the O(batches) scan in
    /// `target_gpu_idle`).
    pub(super) gpu_busy: Vec<usize>,
    /// Incremental index: per-GPU count of batches in `Loading` state —
    /// the billing classes' "loading bills like execution" test, O(1)
    /// dense lookup instead of the historical per-interval batch scan.
    pub(super) gpu_loading: Vec<usize>,
    /// Delta-maintained billing aggregates (`sim::billing`): per-GPU
    /// class + per-class running sums, updated through
    /// `Engine::reclassify_gpu` on every state change.
    pub(super) bill: BillingIndex,
    /// Outstanding queue-wakeup tokens per function: superseded checks
    /// are cancelled in O(1) instead of being stamped and skipped.
    pub(super) queue_wakeups: Vec<QueueWakeups>,
    /// The single outstanding `GpuTick` per GPU (dense arena; `None` =
    /// exec idle). Re-scheduling cancels the previous tick outright.
    pub(super) tick_tokens: Vec<Option<EventToken>>,
    /// The single outstanding `KeepaliveCheck`: its armed instant and
    /// token. Re-armed (cancel + push) whenever the earliest expiry
    /// moves, so sweeps fire only when something actually expires.
    pub(super) keepalive_armed: Option<(f64, EventToken)>,
    /// Arrival stream cursor: request indices sorted by arrival time;
    /// only the next pending arrival lives in the event queue, so the
    /// heap stays O(in-flight events) instead of O(requests).
    pub(super) arrival_order: Vec<usize>,
    pub(super) arrival_cursor: usize,
    /// Functions sharing each model (staging copies are per-model).
    pub(super) model_peers: BTreeMap<&'static str, Vec<usize>>,
    /// Models hosted by *peer zones* of a sharded run (`sim::sharded`),
    /// refreshed at zone-window boundaries. Empty for single-zone runs —
    /// the cross-zone pricing hook in `make_resident` short-circuits on
    /// emptiness, so zones=1 performs zero additional float operations.
    pub(super) peer_models: BTreeSet<&'static str>,
    /// Built-in observer #1: the per-request metrics sink.
    pub metrics: RunMetrics,
    /// Built-in observer #2: the billing model pricing each aggregate
    /// sample into the run's `CostTracker` (`sim::observe::BilledCost`).
    pub(super) cost_obs: BilledCost,
    /// Built-in observer #3 (opt-in): the coarse per-billing-class
    /// time-series sampler (`Engine::enable_bill_series`).
    pub(super) series: Option<BillSeriesSampler>,
    /// Attached observers: push-based sinks receiving every hook, in
    /// attach order (borrowed event data only — they cannot touch the
    /// built-ins' state).
    pub(super) observers: Vec<Box<dyn Observer>>,
    pub stats: RunStats,
    pub(super) last_bill_t: f64,
    /// Serverful: function → dedicated GPU.
    pub(super) dedicated: BTreeMap<usize, GpuId>,
    pub(super) requests: Vec<Request>,
    /// request id → index in `requests` (dispatch-path lookup).
    pub(super) request_index: HashMap<u64, usize>,
    pub(super) duration_s: f64,
    /// Fault injector (`sim::fault`), built only when `cfg.faults` is
    /// `Some` — the faultless fast path carries a `None` and performs
    /// zero fault work.
    pub(super) injector: Option<FaultInjector>,
    /// Requests that have arrived so far — the conservation invariant's
    /// right-hand side (`completed + failed + in_flight == arrivals`).
    pub(super) arrived: usize,
    /// Requests currently sleeping in a retry backoff: exactly the live
    /// `RetryWake` events (brute-checked in `check_indexes`).
    pub(super) retry_pending: usize,
    /// Per-request transient-retry attempts (fault injection only).
    pub(super) retry_count: HashMap<u64, u32>,
    /// Degrade slowdown factor per GPU (dense arena; 1.0 = full speed).
    /// Non-unit exactly while that GPU's restore event is outstanding.
    pub(super) degrade_factor: Vec<f64>,
    /// The single outstanding `GpuRestore` per degraded GPU (dense
    /// arena). A crash mid-degrade cancels the episode through this
    /// handle, so a restore never fires on a repaired-cold GPU.
    pub(super) restore_tokens: Vec<Option<EventToken>>,
    /// §"Cold-start strategies" policy (sixth trait in the bundle):
    /// tiered (historical path), snapshot-restore, or pipelined. Only
    /// consulted when `cfg.cold_start` is `Some`.
    pub(super) cold_start: Box<dyn ColdStartPolicy>,
    /// In-flight snapshot builds: `(function, node)` → the pending
    /// `SnapshotReady` token (`sim::coldstart`).
    pub(super) snap_builds: BTreeMap<(usize, usize), EventToken>,
    /// In-flight pipelined sibling shards, keyed by synthetic flow id.
    pub(super) pipe_shards: BTreeMap<u64, PipeShard>,
    /// Pipelined-load state per owning batch id.
    pub(super) pipe_runs: BTreeMap<u64, PipeRun>,
    /// Functions whose next cold start is forced onto the tiered path
    /// (their last pipelined load was killed by a fault). Cleared on
    /// the next completed cold load.
    pub(super) pipe_fallback: BTreeSet<usize>,
    /// Resident snapshot GB across all node caches — the storage
    /// surcharge integrand (`sim::billing::bill_interval`). Identically
    /// 0.0 when `cfg.cold_start` is `None`.
    pub(super) snap_gb_total: f64,
}

impl Engine {
    pub fn new(
        cfg: SystemConfig,
        mut cluster: Cluster,
        workload: Workload,
        seed: u64,
    ) -> Self {
        let queues = workload
            .functions
            .iter()
            .map(|f| BatchQueue::new(f.id, &f.model))
            .collect();
        let gpu_map = cluster.dense_map();
        let n_gpus = gpu_map.len();
        let n_nodes = cluster.nodes.len();
        let n_fns = workload.functions.len();
        let mut model_peers: BTreeMap<&'static str, Vec<usize>> = BTreeMap::new();
        for f in &workload.functions {
            model_peers.entry(f.model.name).or_default().push(f.id);
        }
        if let Some(t) = cfg.tiers {
            cluster.set_host_cache_gb(t.host_cache_gb);
        }
        // Own seeded RNG stream (`FAULT_STREAM`): enabling faults never
        // perturbs workload or policy draws, and `faults: None` builds
        // no injector at all.
        let injector = cfg.faults.map(|f| FaultInjector::new(f, seed));
        // Failure-aware routing (off by default): install the cluster's
        // failure-history tracker so crash/degrade observations feed the
        // router's score penalty. When the knob is off the tracker stays
        // `None` and `failure_penalty` is exactly 0.0.
        if let Some(f) = cfg.faults {
            if f.failure_aware {
                cluster.enable_failure_tracking(f.failure_tau_s, f.failure_penalty_gb);
            }
        }
        let PolicyBundle { preload, batching, offload, billing, cache, cold_start } =
            cfg.bundle(seed);
        let mut e = Engine {
            keepalive: KeepAlive::new(cfg.keepalive_s.min(1e12)),
            preload,
            batching,
            offload,
            cache,
            cfg,
            cluster,
            registry: BackboneRegistry::new(),
            functions: workload.functions,
            rates: workload.rates,
            queues,
            gpu_map,
            execs: vec![GpuExec::default(); n_gpus],
            events: EventQueue::new(),
            now: 0.0,
            batches: BTreeMap::new(),
            next_batch: 1,
            flows: FlowNet::new(n_nodes),
            load_runs: BTreeMap::new(),
            blocked: BTreeMap::new(),
            active: BTreeSet::new(),
            fn_inflight: vec![0; n_fns],
            gpu_busy: vec![0; n_gpus],
            gpu_loading: vec![0; n_gpus],
            bill: BillingIndex::default(),
            queue_wakeups: vec![QueueWakeups::default(); n_fns],
            tick_tokens: vec![None; n_gpus],
            keepalive_armed: None,
            arrival_order: Vec::new(),
            arrival_cursor: 0,
            model_peers,
            peer_models: BTreeSet::new(),
            metrics: RunMetrics::default(),
            cost_obs: BilledCost::new(billing),
            series: None,
            observers: Vec::new(),
            stats: RunStats::default(),
            last_bill_t: 0.0,
            dedicated: BTreeMap::new(),
            request_index: workload
                .requests
                .iter()
                .enumerate()
                .map(|(i, r)| (r.id, i))
                .collect(),
            requests: workload.requests,
            duration_s: workload.duration_s,
            injector,
            arrived: 0,
            retry_pending: 0,
            retry_count: HashMap::new(),
            degrade_factor: vec![1.0; n_gpus],
            restore_tokens: vec![None; n_gpus],
            cold_start,
            snap_builds: BTreeMap::new(),
            pipe_shards: BTreeMap::new(),
            pipe_runs: BTreeMap::new(),
            pipe_fallback: BTreeSet::new(),
            snap_gb_total: 0.0,
        };
        e.metrics.duration_s = e.duration_s;
        e.setup();
        // Classify the freshly-deployed cluster into the billing
        // aggregates; from here on every mutation maintains them by
        // delta.
        e.init_billing();
        // Fault injection: draw the first crash of every GPU (no-op
        // when `cfg.faults` is `None`).
        e.schedule_initial_crashes();
        e
    }

    pub(super) fn spec(&self, f: usize) -> &FunctionSpec {
        &self.functions[f]
    }

    /// Schedule the arrival stream, then let the preload policy stage
    /// the deployment (PCKP plan, serverful residency, container
    /// staging, …). Arrivals are streamed: the stream is sorted by
    /// arrival time and each arrival schedules its successor, so the
    /// event heap holds one pending arrival instead of all of them.
    fn setup(&mut self) {
        let mut order: Vec<usize> = (0..self.requests.len()).collect();
        let arrivals: Vec<f64> = self.requests.iter().map(|r| r.arrival_s).collect();
        order.sort_by(|&a, &b| arrivals[a].total_cmp(&arrivals[b]));
        self.arrival_order = order;
        self.arrival_cursor = 0;
        self.schedule_next_arrival();
        let mut env = PolicyEnv {
            cluster: &mut self.cluster,
            registry: &mut self.registry,
            functions: &self.functions,
            rates: &self.rates,
            sharing: self.cfg.backbone_sharing,
            dedicated: &mut self.dedicated,
            stats: &mut self.stats,
        };
        self.preload.deploy(&mut env);
    }

    /// Push the next pending arrival (if any) from the sorted stream.
    pub(super) fn schedule_next_arrival(&mut self) {
        if let Some(&i) = self.arrival_order.get(self.arrival_cursor) {
            self.arrival_cursor += 1;
            self.events.push(self.requests[i].arrival_s, EventKind::Arrival(i));
        }
    }

    /// Process one event. Returns false when the queue is drained.
    /// Public so tests can interleave invariant checks mid-run.
    pub fn step(&mut self) -> bool {
        let Some(ev) = self.events.pop() else { return false };
        self.stats.events_processed += 1;
        let in_queue = self.events.len() + 1;
        self.stats.peak_event_queue = self.stats.peak_event_queue.max(in_queue);
        debug_assert!(ev.t >= self.now - 1e-6, "time went backwards");
        self.bill_interval(ev.t);
        self.now = ev.t;
        match ev.kind {
            EventKind::Arrival(i) => self.on_arrival(i),
            // A QueueCheck that fires is current by construction: every
            // queue mutation cancels its superseded checks outright.
            EventKind::QueueCheck(f) => self.try_dispatch_all(Some(f)),
            EventKind::LoadDone(b) => {
                // A firing load event is current by construction (stale
                // ones are cancelled on retime); drop the token so the
                // segment step doesn't cancel a dead handle. Flat-path
                // loads track theirs on the batch (crash-cancel handle).
                if let Some(run) = self.load_runs.get_mut(&b) {
                    run.token = None;
                }
                if let Some(batch) = self.batches.get_mut(&b) {
                    batch.load_token = None;
                }
                self.on_load_event(b)
            }
            EventKind::GpuTick(g) => {
                self.tick_tokens[self.gpu_map.dense(g)] = None; // just fired
                self.on_gpu_tick(g);
            }
            EventKind::KeepaliveCheck => {
                self.stats.keepalive_checks += 1;
                self.keepalive_armed = None;
                self.on_keepalive();
                self.arm_keepalive();
            }
            // Fault injection (`sim::fault`) — these kinds are only ever
            // scheduled when `cfg.faults` is `Some`.
            EventKind::GpuCrash(g) => self.on_gpu_crash(g),
            EventKind::GpuRecover(g) => self.on_gpu_recover(g),
            EventKind::RetryWake(id) => self.on_retry_wake(id),
            // Correlated failure domains + degraded mode: scheduled only
            // when the matching `FaultSpec` sub-spec is present.
            EventKind::NodeCrash(n) => self.on_node_crash(n),
            EventKind::NodeRecover(n) => self.on_node_recover(n),
            EventKind::ZoneOutage => self.on_zone_outage(),
            EventKind::ZoneRecover => self.on_zone_recover(),
            EventKind::GpuDegrade(g) => self.on_gpu_degrade(g),
            EventKind::GpuRestore(g) => self.on_gpu_restore(g),
            // Cold-start strategies (`sim::coldstart`) — scheduled only
            // when `cfg.cold_start` selects a non-tiered strategy.
            EventKind::SnapshotReady(f, n) => self.on_snapshot_ready(f, n),
            EventKind::ShardDone(id) => self.on_shard_done(id),
            EventKind::ConsolidateDone(id) => self.on_consolidate_done(id),
        }
        // Fold this event's memory mutations into the billing
        // aggregates (O(GPUs touched)), so the next interval samples the
        // post-event state in O(1).
        self.drain_billing_dirty();
        self.stats.events_cancelled = self.events.cancelled();
        true
    }

    pub fn run(mut self) -> (RunMetrics, CostTracker, RunStats) {
        while self.step() {}
        self.finish()
    }

    /// Drain the event queue, then return the full output surface
    /// (metrics, cost, stats, and the opt-in bill series).
    pub fn run_full(mut self) -> RunOutput {
        while self.step() {}
        self.finish_full()
    }

    /// Process every event at `t <= boundary`, then stop (conservative
    /// zone-window execution, `sim::sharded`). Peeking never reorders
    /// pops, so a run chopped into windows is bit-identical to an
    /// unchopped one.
    pub fn step_until(&mut self, boundary: f64) {
        while let Some(t) = self.events.next_t() {
            if t > boundary {
                break;
            }
            self.step();
        }
    }

    /// Models with at least one shared-backbone host on this engine's
    /// cluster — the payload exchanged between zones at window
    /// boundaries (`sim::sharded`).
    pub fn hosted_models(&self) -> BTreeSet<&'static str> {
        self.model_peers
            .keys()
            .copied()
            .filter(|&m| !self.registry.hosts(m).is_empty())
            .collect()
    }

    /// Install the models hosted by peer zones (see `sim::sharded`).
    /// Affects only the *pricing* of future cold backbone loads — it
    /// creates no events, so a drained zone stays drained.
    pub fn set_peer_models(&mut self, peers: BTreeSet<&'static str>) {
        self.peer_models = peers;
    }

    /// Final billing to the end of the workload window, then the
    /// billing model's settlement (serverful: flat GPU-hours) and the
    /// observers' `on_finish` hooks.
    fn close(&mut self) {
        let end = self.duration_s.max(self.now);
        self.stats.events_cancelled = self.events.cancelled();
        self.bill_interval(end);
        let dedicated: BTreeSet<GpuId> = self.dedicated.values().cloned().collect();
        self.cost_obs.finalize(dedicated.len(), end);
        // Throughput denominators use the makespan (last completion),
        // not the arrival window — saturating workloads drain past it.
        let makespan = self
            .metrics
            .outcomes
            .iter()
            .map(|o| o.arrival_s + o.e2e_s)
            .fold(self.duration_s, f64::max);
        self.metrics.duration_s = makespan;
        if let Some(s) = self.series.as_mut() {
            s.on_finish(end);
        }
        for ob in &mut self.observers {
            ob.on_finish(end);
        }
    }

    /// Historical output tuple — a projection of [`Engine::finish_full`].
    pub fn finish(self) -> (RunMetrics, CostTracker, RunStats) {
        let out = self.finish_full();
        (out.metrics, out.cost, out.stats)
    }

    /// Close the run and move out everything it produced.
    pub fn finish_full(mut self) -> RunOutput {
        self.close();
        RunOutput {
            metrics: self.metrics,
            cost: self.cost_obs.cost,
            stats: self.stats,
            bill_series: self.series.map(BillSeriesSampler::into_series),
        }
    }

    // ------------------------------------------------------- observers

    /// Attach an [`Observer`]; it receives every hook, in attach
    /// order. The current per-GPU billing
    /// classification is replayed to it first (`from == None` marks
    /// snapshot entries), so an observer attached after construction
    /// still starts from a consistent picture. Push-only: the engine
    /// does not hand observers back — share state out (e.g.
    /// `Arc<Mutex<_>>`).
    pub fn attach_observer(&mut self, mut ob: Box<dyn Observer>) {
        let t = self.now;
        for (g, class) in self.bill_classes() {
            ob.on_gpu_reclass(t, g, None, class);
        }
        self.observers.push(ob);
    }

    /// Enable the opt-in coarse per-billing-class time-series sampler
    /// (bucket width in sim seconds). The series comes back in
    /// [`RunOutput::bill_series`]. Off by default; when off the run
    /// takes zero additional samples and allocates nothing.
    pub fn enable_bill_series(&mut self, bucket_s: f64) {
        self.series = Some(BillSeriesSampler::new(bucket_s));
    }

    /// A request completed: the series sampler and attached observers
    /// see `&outcome`, then the built-in metrics sink takes it by move
    /// (no clone on the hot path). Observers hold no reference into the
    /// engine, so this ordering is unobservable to them — metrics stay
    /// unperturbable either way.
    pub(super) fn emit_request_complete(&mut self, outcome: RequestOutcome) {
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_request_complete(t, &outcome);
        }
        for ob in &mut self.observers {
            ob.on_request_complete(t, &outcome);
        }
        self.metrics.record(outcome);
    }

    /// A GPU's billing class transitioned (`sim::billing::reclassify_gpu`).
    pub(super) fn emit_gpu_reclass(&mut self, g: GpuId, from: Option<BillClass>, to: BillClass) {
        if self.series.is_none() && self.observers.is_empty() {
            return;
        }
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_gpu_reclass(t, g, from, to);
        }
        for ob in &mut self.observers {
            ob.on_gpu_reclass(t, g, from, to);
        }
    }

    /// A function entered/left the keep-alive warm set.
    pub(super) fn emit_keepalive(&mut self, f: usize, warm: bool) {
        if self.series.is_none() && self.observers.is_empty() {
            return;
        }
        let t = self.now;
        if let Some(s) = self.series.as_mut() {
            s.on_keepalive(t, f, warm);
        }
        for ob in &mut self.observers {
            ob.on_keepalive(t, f, warm);
        }
    }

    /// Keep the single keep-alive sweep armed at exactly the earliest
    /// expiry. When a `touch` moves the minimum later, the superseded
    /// sweep is *cancelled* and re-pushed at the new instant (O(1) +
    /// O(log warm) for `next_expiry`), so sweeps fire only when
    /// something actually expires — no no-op wakeups.
    pub(super) fn arm_keepalive(&mut self) {
        let want = self
            .keepalive
            .next_expiry()
            .filter(|t| t.is_finite())
            .map(|t| t.max(self.now));
        match (want, self.keepalive_armed) {
            (Some(t), Some((at, _))) if t == at => {} // already right
            (Some(t), prev) => {
                if let Some((_, tok)) = prev {
                    self.events.cancel(tok);
                }
                let tok = self.events.push(t, EventKind::KeepaliveCheck);
                self.keepalive_armed = Some((t, tok));
            }
            (None, Some((_, tok))) => {
                self.events.cancel(tok);
                self.keepalive_armed = None;
            }
            (None, None) => {}
        }
    }

    /// Keep-alive expiry: an expired function loses its *instance*. Its
    /// artifacts persist only when the preload policy owns them (they
    /// belong to the provider-side agent, not the instance).
    fn on_keepalive(&mut self) {
        let expired = self.keepalive.expired(self.now);
        let mut freed = false;
        for (f, _) in expired {
            // Warmth ends for every expired function — including those
            // whose artifacts survive (agent-owned) or are mid-flight —
            // so the billing warm counts drop before any eviction below
            // mutates the residency the counts were taken over. The
            // returned snapshot is the function's resident-GPU set,
            // reused for the eviction loop.
            let resident = self.note_function_cold(f);
            if self.preload.retains_artifacts(f) {
                continue;
            }
            if self.fn_inflight[f] > 0 {
                continue; // mid-flight; next completion re-arms keep-alive
            }
            // Only the GPUs where this function actually resides (the
            // per-function index) — dirtying every GPU here would force
            // a full routing-index repair on the next route.
            for g in resident {
                let gpu = self.cluster.gpu_mut(g);
                freed |= gpu.evict_artifact(f, ArtifactKind::Adapter).is_ok();
                freed |= gpu.evict_artifact(f, ArtifactKind::CudaKernel).is_ok();
                freed |= gpu.evict_artifact(f, ArtifactKind::Backbone).is_ok();
                // Context teardown releases CUDA_CONTEXT_GB too.
                freed |= gpu.has_cuda_context(f);
                gpu.destroy_cuda_context(f);
            }
            // Shared backbone: if no warm (or agent-staged) function of
            // this model remains, drop the idle segment.
            if self.cfg.backbone_sharing {
                let model = self.spec(f).model.name;
                let peers: &[usize] =
                    self.model_peers.get(model).map(Vec::as_slice).unwrap_or_default();
                let still_needed = peers.iter().any(|&s| {
                    self.keepalive.is_warm(s, self.now)
                        || self.preload.retains_artifacts(s)
                });
                if !still_needed {
                    for g in self.registry.hosts(model).to_vec() {
                        let r = self.registry.unload(&mut self.cluster, model, g);
                        freed |= r.is_ok();
                    }
                }
            }
        }
        // Evictions freed GPU memory: memory-blocked functions get a
        // retry (without this, a function blocked on an otherwise-idle
        // GPU could starve until an unrelated completion).
        if freed && !self.blocked.is_empty() {
            self.stats.blocked_retries += self.blocked.len();
            self.blocked.clear();
            self.try_dispatch_all(None);
        }
    }

    /// Brute-force re-derivation of every incremental index, asserting
    /// each equals its O(1)/O(log) counterpart. Called from tests
    /// between `step`s; not used by the simulation itself.
    pub fn check_indexes(&self) {
        use crate::sim::dispatch::BatchState;
        assert_eq!(self.gpu_busy.len(), self.cluster.n_gpus());
        for (d, &n) in self.gpu_busy.iter().enumerate() {
            let g = self.gpu_map.id(d);
            let brute = self
                .batches
                .values()
                .filter(|b| {
                    b.gpu == g
                        && matches!(b.state, BatchState::Loading | BatchState::Prefill)
                })
                .count();
            assert_eq!(n, brute, "gpu_busy[{g:?}] drifted");
        }
        for f in 0..self.functions.len() {
            let brute = self.batches.values().filter(|b| b.function == f).count();
            assert_eq!(self.fn_inflight[f], brute, "fn_inflight[{f}] drifted");
        }
        for f in 0..self.queues.len() {
            assert_eq!(
                self.active.contains(&f),
                !self.queues[f].is_empty(),
                "active set drifted for function {f}"
            );
        }
        for &f in self.blocked.keys() {
            assert!(
                !self.queues[f].is_empty(),
                "blocked function {f} has an empty queue"
            );
        }
        // Conservation (fault-injection tentpole invariant): every
        // arrival is queued, in a batch, sleeping in a retry backoff,
        // completed, or failed — `completed + failed + in_flight ==
        // arrivals` holds at every step, including mid-run with GPUs
        // down. With faults off the failed/retry terms are identically
        // zero and this reduces to the historical queued-or-batched-or-
        // completed accounting.
        let queued: usize = self.queues.iter().map(|q| q.len()).sum();
        let in_batches: usize = self.batches.values().map(|b| b.requests.len()).sum();
        assert_eq!(
            self.metrics.outcomes.len()
                + self.metrics.failed as usize
                + queued
                + in_batches
                + self.retry_pending,
            self.arrived,
            "request conservation violated: completed + failed + in_flight != arrivals"
        );
        let live_retries = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::RetryWake(_)))
            .count();
        assert_eq!(
            live_retries, self.retry_pending,
            "retry_pending != live RetryWake events"
        );
        // Health: a down GPU holds no in-flight batches (its batches are
        // killed at crash/outage time and the router never picks it).
        // Degraded GPUs are *not* down and may hold batches.
        for (&b, batch) in &self.batches {
            assert!(
                self.cluster.gpu_is_up(batch.gpu),
                "batch {b} in flight on a down GPU {:?}",
                batch.gpu
            );
        }
        // Degrade coherence: a non-unit slowdown factor exists exactly
        // while its restore event is live, only on an up GPU, and the
        // exec's service rate is exactly the factor's reciprocal.
        let restore_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::GpuRestore(_)))
            .count();
        let live_restores = self.restore_tokens.iter().flatten().count();
        assert_eq!(restore_events, live_restores, "untracked GpuRestore events");
        for (d, tok) in self.restore_tokens.iter().enumerate() {
            let g = self.gpu_map.id(d);
            match tok {
                Some(tok) => {
                    let p = self.events.get(*tok).expect("tracked GpuRestore token is dead");
                    assert!(
                        matches!(p.kind, &EventKind::GpuRestore(eg) if eg == g),
                        "restore token for {g} points at {:?}",
                        p.kind
                    );
                    assert!(
                        self.degrade_factor[d] >= 1.0,
                        "degrade episode on {g} with factor {}",
                        self.degrade_factor[d]
                    );
                    assert!(self.cluster.gpu_is_up(g), "degraded GPU {g} is down");
                }
                None => assert_eq!(
                    self.degrade_factor[d], 1.0,
                    "lingering degrade factor on {g}"
                ),
            }
            assert_eq!(
                self.execs[d].rate().to_bits(),
                (1.0 / self.degrade_factor[d]).to_bits(),
                "exec rate disagrees with degrade factor on {g}"
            );
        }
        // Timing-wheel structural invariants + the cluster's routing
        // indexes (free-memory order, per-function residency, container
        // residency counts).
        self.events.check_invariants();
        self.cluster.check_index();
        // Billing aggregates: per-GPU classes, integer milli-GB class
        // sums, warm counts, and loading counts vs their brute-force
        // rebuild (the historical full scan, demoted to oracle duty).
        self.check_billing();
        // Keep-alive: the single armed sweep matches its marker exactly.
        let ka_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::KeepaliveCheck))
            .count();
        match self.keepalive_armed {
            Some((at, tok)) => {
                assert_eq!(ka_events, 1, "armed marker but {ka_events} sweeps live");
                let p = self.events.get(tok).expect("armed keep-alive token is dead");
                assert_eq!(p.t.to_bits(), at.to_bits(), "armed instant drifted");
                assert!(matches!(p.kind, &EventKind::KeepaliveCheck));
            }
            None => assert_eq!(ka_events, 0, "live KeepaliveCheck without marker"),
        }
        // GPU ticks: exactly one live tick per busy exec, none for idle.
        let tick_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::GpuTick(_)))
            .count();
        let live_ticks = self.tick_tokens.iter().flatten().count();
        assert_eq!(tick_events, live_ticks, "untracked GpuTick events");
        for (d, tok) in self.tick_tokens.iter().enumerate() {
            let Some(&tok) = tok.as_ref() else { continue };
            let g = self.gpu_map.id(d);
            let p = self.events.get(tok).expect("tracked GpuTick token is dead");
            assert!(
                matches!(p.kind, &EventKind::GpuTick(eg) if eg == g),
                "tick token for {g} points at {:?}",
                p.kind
            );
        }
        for (d, exec) in self.execs.iter().enumerate() {
            assert_eq!(
                self.tick_tokens[d].is_some(),
                exec.next_completion().is_some(),
                "tick presence disagrees with exec state on {}",
                self.gpu_map.id(d)
            );
        }
        // Queue wakeups: the live QueueCheck events are exactly the live
        // tokens, ≤2 per function, only on non-empty queues.
        let mut live_qc = vec![0usize; self.queues.len()];
        for e in self.events.iter() {
            if let &EventKind::QueueCheck(f) = e.kind {
                live_qc[f] += 1;
            }
        }
        for f in 0..self.queues.len() {
            let live_toks = self.queue_wakeups[f]
                .tokens()
                .filter(|&tok| {
                    self.events.get(tok).map_or(false, |p| {
                        assert!(
                            matches!(p.kind, &EventKind::QueueCheck(ff) if ff == f),
                            "wakeup token for {f} points at {:?}",
                            p.kind
                        );
                        true
                    })
                })
                .count();
            assert_eq!(
                live_toks, live_qc[f],
                "function {f}: {} live checks vs {live_toks} live tokens",
                live_qc[f]
            );
            assert!(live_qc[f] <= 2, "function {f} has {} wakeups", live_qc[f]);
            if self.queues[f].is_empty() {
                assert_eq!(live_qc[f], 0, "wakeups armed on an empty queue {f}");
            }
        }
        self.check_flows();
        self.check_coldstart();
    }

    /// Tiered-load invariants: flows ↔ load runs ↔ batches ↔ events stay
    /// mutually consistent, host caches stay within capacity, and the
    /// tier-hit counters conserve (`ram + ssd + remote == tiered loads`).
    fn check_flows(&self) {
        use crate::sim::dispatch::BatchState;
        self.flows.check(self.now);
        // Every flow belongs to a load run currently on that exact
        // transfer segment, scheduled at the event time the run tracks.
        let mut flow_count = 0usize;
        for (node, link, f) in self.flows.iter() {
            // Pipelined shard/consolidation flows carry synthetic ids and
            // are audited by `check_coldstart`, not the load-run index.
            if crate::sim::coldstart::is_pipe_id(f.batch) {
                continue;
            }
            flow_count += 1;
            let run = self.load_runs.get(&f.batch).expect("flow without a load run");
            assert_eq!(run.node, node, "flow node drifted for batch {}", f.batch);
            let seg = &run.segs[run.cursor];
            assert_eq!(seg.link, Some(link), "flow link drifted for batch {}", f.batch);
            assert_eq!(
                f.scheduled_end_s.to_bits(),
                run.cur_end_s.to_bits(),
                "flow/run completion times diverged for batch {}",
                f.batch
            );
        }
        let runs_on_xfer = self
            .load_runs
            .values()
            .filter(|r| r.segs[r.cursor].link.is_some())
            .count();
        assert_eq!(flow_count, runs_on_xfer, "flows ≠ runs on transfer segments");
        // Every load run points at a Loading batch and owns a live
        // LoadDone token at exactly its tracked completion time.
        for (&b, run) in &self.load_runs {
            assert!(run.cursor < run.segs.len(), "run cursor past end for batch {b}");
            let batch = self.batches.get(&b).expect("load run without a batch");
            assert_eq!(batch.state, BatchState::Loading, "run on non-loading batch {b}");
            let tok = run.token.expect("mid-run load without a live token");
            let p = self.events.get(tok).expect("tracked LoadDone token is dead");
            assert!(
                matches!(p.kind, &EventKind::LoadDone(eb) if eb == b),
                "load token for batch {b} points at {:?}",
                p.kind
            );
            assert_eq!(
                p.t.to_bits(),
                run.cur_end_s.to_bits(),
                "scheduled load event drifted for batch {b}"
            );
        }
        // Flat-path Loading batches hold a live token on their own
        // LoadDone (the crash-kill cancel handle); segmented ones track
        // theirs in the run, and non-loading states carry none.
        for (&b, batch) in &self.batches {
            if batch.state != BatchState::Loading {
                assert!(
                    batch.load_token.is_none(),
                    "stale flat load token on batch {b}"
                );
                continue;
            }
            if self.load_runs.contains_key(&b) {
                assert!(
                    batch.load_token.is_none(),
                    "segmented batch {b} carries a flat token"
                );
            } else if self.pipe_held(b) {
                // A pipelined batch holding for its sibling shards has
                // retired its own run; the next event is a ShardDone.
                assert!(
                    batch.load_token.is_none(),
                    "shard-held batch {b} carries a flat token"
                );
            } else {
                let tok = batch.load_token.expect("flat loading batch without a token");
                let p = self.events.get(tok).expect("flat LoadDone token is dead");
                assert!(
                    matches!(p.kind, &EventKind::LoadDone(eb) if eb == b),
                    "flat load token for batch {b} points at {:?}",
                    p.kind
                );
            }
        }
        // One live LoadDone per Loading batch, segmented or flat.
        let load_events = self
            .events
            .iter()
            .filter(|e| matches!(e.kind, &EventKind::LoadDone(_)))
            .count();
        let loading = self
            .batches
            .iter()
            .filter(|(&b, batch)| {
                batch.state == BatchState::Loading && !self.pipe_held(b)
            })
            .count();
        assert_eq!(load_events, loading, "LoadDone events ≠ loading batches");
        // Host caches honor their capacity; tier hits conserve.
        for node in &self.cluster.nodes {
            assert!(
                node.cache.used_gb() <= node.cache.capacity_gb + 1e-9,
                "host cache over capacity"
            );
        }
        assert_eq!(
            self.stats.tier_hits_ram + self.stats.tier_hits_ssd
                + self.stats.tier_hits_remote,
            self.stats.tiered_cold_loads,
            "tier hit counters do not conserve"
        );
    }

    /// Pending event count (hygiene tests / fleet telemetry).
    pub fn event_queue_len(&self) -> usize {
        self.events.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;
    use crate::trace::{Pattern, TraceSpec};

    fn workload(n_fns: usize, rate: f64, dur: f64, pattern: Pattern) -> Workload {
        let functions: Vec<FunctionSpec> = (0..n_fns)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let traces: Vec<Vec<Request>> = (0..n_fns)
            .map(|i| TraceSpec::new(i, pattern, rate, 9 + i as u64).generate(dur))
            .collect();
        Workload {
            functions,
            requests: crate::trace::merge(traces),
            duration_s: dur,
            rates: vec![rate; n_fns],
        }
    }

    fn run(cfg: SystemConfig, w: Workload) -> (RunMetrics, CostTracker, RunStats) {
        Engine::new(cfg, Cluster::new(1, 2, 4), w, 1).run()
    }

    #[test]
    fn conservation_all_requests_served() {
        let w = workload(4, 0.05, 1800.0, Pattern::Normal);
        let n = w.requests.len();
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m.outcomes.len(), n, "arrived == completed");
    }

    #[test]
    fn serverful_has_zero_cold_start() {
        let w = workload(2, 0.05, 900.0, Pattern::Predictable);
        let (m, _, _) = run(SystemConfig::vllm(), w);
        for o in &m.outcomes {
            assert_eq!(o.cold_start_s(), 0.0, "vLLM never cold-starts");
        }
    }

    #[test]
    fn serverless_lora_beats_serverless_llm_on_ttft() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (lora, _, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (sllm, _, _) = run(SystemConfig::serverless_llm(), w);
        assert!(
            lora.ttft().mean < sllm.ttft().mean,
            "lora {} vs sllm {}",
            lora.ttft().mean,
            sllm.ttft().mean
        );
    }

    #[test]
    fn preload_reduces_ttft_vs_npl() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (full, _, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (npl, _, _) = run(SystemConfig::npl(), w);
        assert!(full.ttft().mean <= npl.ttft().mean * 1.01);
    }

    #[test]
    fn predictive_plugin_runs_and_helps_vs_npl() {
        // The policy-API proof: Predictive-LoRA runs end-to-end as a pure
        // plug-in, conserves requests, and its forecast-driven staging
        // does not lose to no-preloading at all.
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let n = w.requests.len();
        let (pred, _, stats) = run(SystemConfig::predictive(), w.clone());
        assert_eq!(pred.outcomes.len(), n);
        assert!(stats.preload_decisions > 0, "forecast never staged anything");
        let (npl, _, _) = run(SystemConfig::npl(), w);
        assert!(
            pred.ttft().mean <= npl.ttft().mean * 1.05,
            "predictive {} vs npl {}",
            pred.ttft().mean,
            npl.ttft().mean
        );
    }

    #[test]
    fn sharing_cheaper_than_nbs() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (_, c_full, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (_, c_nbs, _) = run(SystemConfig::nbs(), w);
        assert!(
            c_full.total_usd() < c_nbs.total_usd(),
            "shared {} vs NBS {}",
            c_full.total_usd(),
            c_nbs.total_usd()
        );
    }

    #[test]
    fn serverless_cost_scales_with_usage_not_walltime() {
        // Half the request rate ⇒ materially cheaper (pay-per-use);
        // serverful cost identical.
        let w1 = workload(2, 0.04, 3600.0, Pattern::Normal);
        let w2 = workload(2, 0.01, 3600.0, Pattern::Normal);
        let (_, c1, _) = run(SystemConfig::serverless_lora(), w1.clone());
        let (_, c2, _) = run(SystemConfig::serverless_lora(), w2.clone());
        assert!(c2.total_usd() < c1.total_usd());
        let (_, v1, _) = run(SystemConfig::vllm(), w1);
        let (_, v2, _) = run(SystemConfig::vllm(), w2);
        assert!((v1.total_usd() - v2.total_usd()).abs() / v1.total_usd() < 0.05);
    }

    #[test]
    fn bursty_benefits_from_batching() {
        // Adaptive batching must produce batches > 1 under bursts.
        let w = workload(2, 0.2, 1800.0, Pattern::Bursty);
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        assert!(m.peak_batch() > 1, "peak batch {}", m.peak_batch());
    }

    #[test]
    fn ttfts_nonnegative_and_ordered() {
        let w = workload(4, 0.05, 900.0, Pattern::Bursty);
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        for o in &m.outcomes {
            assert!(o.ttft_s >= 0.0);
            assert!(o.e2e_s >= o.ttft_s);
            assert!(o.tpot_s >= 0.0);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let w = workload(3, 0.05, 900.0, Pattern::Normal);
        let (m1, c1, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (m2, c2, _) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m1.outcomes.len(), m2.outcomes.len());
        assert!((m1.ttft().mean - m2.ttft().mean).abs() < 1e-12);
        assert!((c1.total_usd() - c2.total_usd()).abs() < 1e-12);
    }

    #[test]
    fn keepalive_checks_do_not_scale_with_completions() {
        // Regression for the event-queue flood: the engine used to push
        // one `KeepaliveCheck` per completion; now exactly one is armed
        // at a time, so the processed count tracks expiry *windows*.
        let w = workload(4, 0.5, 600.0, Pattern::Bursty);
        let n = w.requests.len();
        let (m, _, stats) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m.outcomes.len(), n);
        assert!(n > 300, "workload too small for the regression: {n}");
        assert!(
            stats.keepalive_checks <= 32,
            "keepalive sweeps grew with completions: {} for {} requests",
            stats.keepalive_checks,
            n
        );
        // Streamed arrivals: the heap never holds the whole trace.
        assert!(
            stats.peak_event_queue < n / 2,
            "peak event queue {} vs {} requests",
            stats.peak_event_queue,
            n
        );
    }

    #[test]
    fn supersession_cancels_instead_of_skipping() {
        // The timing-wheel contract: superseded QueueCheck/GpuTick/
        // KeepaliveCheck events are cancelled outright (counted in
        // events_cancelled), so every event the engine processes is
        // current — there is no stale-skip path left to take.
        let w = workload(4, 0.2, 900.0, Pattern::Bursty);
        let n = w.requests.len();
        let (m, _, stats) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m.outcomes.len(), n);
        assert!(
            stats.events_cancelled > 0,
            "bursty traffic must supersede some scheduled events"
        );
        // Fired events amortize to a handful per request once stale
        // entries stop flowing through the pop path.
        assert!(
            stats.events_processed < 16 * n as u64,
            "{} events for {} requests",
            stats.events_processed,
            n
        );
    }

    /// `n` requests to one function, spaced `gap_s` apart — far beyond
    /// the keep-alive window, so every request is an isolated cold start
    /// and no two loads ever share a link.
    fn spaced_workload(n: usize, gap_s: f64) -> Workload {
        let functions = vec![FunctionSpec::new(0, ModelProfile::llama2_7b(), 0)];
        let requests: Vec<Request> = (0..n)
            .map(|i| Request {
                id: i as u64,
                function: 0,
                arrival_s: i as f64 * gap_s,
                prompt_tokens: 256,
                output_tokens: 64,
            })
            .collect();
        Workload {
            functions,
            requests,
            duration_s: n as f64 * gap_s,
            rates: vec![1.0 / gap_s],
        }
    }

    #[test]
    fn solo_tiered_loads_are_bit_identical_to_the_flat_path() {
        // The tiered store's zero-cost-abstraction contract: with the
        // cache disabled and the NVMe seeded (the flat model's implicit
        // assumptions), an uncontended run must reproduce the flat
        // latencies bit-for-bit — solo flows honor the engine's
        // pre-folded nominal ends verbatim, never through arithmetic.
        let w = spaced_workload(5, 400.0);
        let tiered = SystemConfig::npl()
            .with_tiers(TierSpec { host_cache_gb: 0.0, ..TierSpec::default() });
        let (mf, _, _) = run(SystemConfig::npl(), w.clone());
        let (mt, _, st) = run(tiered, w);
        assert!(st.tiered_cold_loads >= 2, "no tiered loads exercised");
        assert_eq!(st.load_retimes, 0, "solo flows must never retime");
        assert_eq!(st.tier_hits_ssd, st.tiered_cold_loads, "all loads hit NVMe");
        assert_eq!(mf.outcomes.len(), mt.outcomes.len());
        for (a, b) in mf.outcomes.iter().zip(&mt.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(
                a.ttft_s.to_bits(),
                b.ttft_s.to_bits(),
                "request {}: flat {} vs tiered {}",
                a.id,
                a.ttft_s,
                b.ttft_s
            );
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
        }
    }

    #[test]
    fn concurrent_cold_loads_contend_and_stretch_ttft() {
        // Four functions cold-start near-simultaneously on one node
        // (sharing off, so each pulls its own checkpoint): the shared
        // NVMe/PCIe links fair-share and every load stretches. The flat
        // model charges all four the solo latency — the contention gap
        // this PR exists to close.
        let cfg = SystemConfig {
            name: "npl-nosharing",
            backbone_sharing: false,
            ..SystemConfig::npl()
        };
        let functions: Vec<FunctionSpec> = (0..4)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let requests: Vec<Request> = (0..4)
            .map(|i| Request {
                id: i as u64,
                function: i,
                arrival_s: 0.001 * i as f64,
                prompt_tokens: 256,
                output_tokens: 64,
            })
            .collect();
        let w = Workload {
            functions,
            requests,
            duration_s: 120.0,
            rates: vec![0.01; 4],
        };
        let cluster = || Cluster::new(1, 4, 4);
        let tiered =
            cfg.clone().with_tiers(TierSpec { host_cache_gb: 0.0, ..TierSpec::default() });
        let (mf, _, _) = Engine::new(cfg, cluster(), w.clone(), 1).run();
        let (mt, _, st) = Engine::new(tiered, cluster(), w, 1).run();
        assert!(st.load_retimes > 0, "concurrent flows never retimed");
        assert!(st.tiered_cold_loads >= 4, "expected 4 cold loads");
        assert_eq!(mf.outcomes.len(), 4);
        assert_eq!(mt.outcomes.len(), 4);
        assert!(
            mt.ttft().mean > mf.ttft().mean * 1.2,
            "4-way link contention must stretch TTFT: tiered {} vs flat {}",
            mt.ttft().mean,
            mf.ttft().mean
        );
    }

    #[test]
    fn host_cache_turns_repeat_cold_starts_into_ram_hits() {
        // Cold → cold → cold on one function with the checkpoint cache
        // on: the first load reads NVMe and admits the checkpoint; the
        // later ones (keep-alive long expired) hit host RAM and load
        // strictly faster.
        let w = spaced_workload(3, 400.0);
        let (m, _, st) =
            run(SystemConfig::npl().with_tiers(TierSpec::default()), w);
        assert!(st.tier_hits_ssd >= 1, "first load must read NVMe");
        assert!(st.tier_hits_ram >= 1, "repeat load must hit the host cache");
        assert_eq!(
            st.tier_hits_ram + st.tier_hits_ssd + st.tier_hits_remote,
            st.tiered_cold_loads
        );
        let first = m.outcomes.iter().find(|o| o.id == 0).unwrap();
        let second = m.outcomes.iter().find(|o| o.id == 1).unwrap();
        assert_eq!(first.backbone_tier, Some(crate::artifact::Tier::Ssd));
        assert_eq!(second.backbone_tier, Some(crate::artifact::Tier::ContainerRam));
        assert!(
            second.ttft_s < first.ttft_s,
            "RAM-tier cold start must beat the NVMe one: {} vs {}",
            second.ttft_s,
            first.ttft_s
        );
    }

    #[test]
    fn tiered_flow_state_matches_bruteforce_mid_run_multi_seed() {
        // The tiered analogue of the index check below: flows ↔ runs ↔
        // batches ↔ events stay mutually consistent at every point of a
        // bursty contended run, across seeds, and the tier-hit counters
        // conserve (asserted inside check_indexes → check_flows).
        let cfg = SystemConfig {
            name: "npl-nosharing",
            backbone_sharing: false,
            ..SystemConfig::npl()
        }
        .with_tiers(TierSpec { host_cache_gb: 16.0, ..TierSpec::default() });
        for seed in [1u64, 7, 23] {
            let w = workload(4, 0.1, 600.0, Pattern::Bursty);
            let n = w.requests.len();
            let mut e = Engine::new(cfg.clone(), Cluster::new(1, 4, 4), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                if steps % 5 == 0 {
                    e.check_indexes();
                }
            }
            e.check_indexes();
            assert!(e.stats.load_retimes > 0, "bursty run never contended");
            assert!(e.stats.tier_hits_ram > 0, "16 GB cache never hit");
            let (m, _, _) = e.finish();
            assert_eq!(m.outcomes.len(), n, "lost requests (seed {seed})");
        }
    }

    #[test]
    fn dormant_faults_are_bit_identical_to_faults_off() {
        // `faults: None` bit-identity, probed from the other side: a
        // spec that provably never fires (astronomical MTBF, zero
        // load-fail probability) builds the injector and walks every
        // fault-gated branch, yet must reproduce the faultless run
        // bit-for-bit — the fault path costs zero perturbation.
        use crate::sim::fault::FaultSpec;
        let w = workload(4, 0.05, 1800.0, Pattern::Bursty);
        let (m_off, c_off, _) = run(SystemConfig::serverless_lora(), w.clone());
        let dormant = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 1e15,
            load_fail_prob: 0.0,
            ..FaultSpec::default()
        });
        let (m_on, c_on, st) = run(dormant, w);
        assert_eq!(st.gpu_crashes, 0, "dormant spec must never crash");
        assert_eq!(st.load_failures, 0);
        assert_eq!(m_off.outcomes.len(), m_on.outcomes.len());
        for (a, b) in m_off.outcomes.iter().zip(&m_on.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "request {}", a.id);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
        }
        assert_eq!(c_off.total_usd().to_bits(), c_on.total_usd().to_bits());
    }

    #[test]
    fn dormant_cold_start_is_bit_identical_multi_seed() {
        // `cold_start: None` bit-identity, probed from the other side:
        // the explicit tiered policy walks every cold-start-gated branch
        // (plan hooks, completion hook, surcharge integrand refresh) yet
        // must reproduce the knobless tiered run bit-for-bit, across
        // seeds. The `None` side of the identity is the historical
        // golden/parity suite itself, which this PR leaves untouched.
        use crate::coldstart::{ColdStartKind, ColdStartSpec};
        use crate::sim::config::TierSpec;
        for seed in [1u64, 7, 23] {
            let w = workload(4, 0.05, 1800.0, Pattern::Bursty);
            let base = SystemConfig::serverless_lora().with_tiers(TierSpec::default());
            let (m_off, c_off, _) =
                Engine::new(base.clone(), Cluster::new(1, 2, 4), w.clone(), seed).run();
            let tiered = base.with_cold_start(ColdStartSpec::uniform(ColdStartKind::Tiered));
            let (m_on, c_on, st) =
                Engine::new(tiered, Cluster::new(1, 2, 4), w, seed).run();
            assert_eq!(
                st.snapshot_builds + st.snapshot_restores + st.pipelined_loads,
                0,
                "the tiered strategy must touch no snapshot/pipeline machinery"
            );
            assert_eq!(m_off.outcomes.len(), m_on.outcomes.len());
            for (a, b) in m_off.outcomes.iter().zip(&m_on.outcomes) {
                assert_eq!(a.id, b.id);
                assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "request {}", a.id);
                assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
            }
            assert_eq!(
                c_off.total_usd().to_bits(),
                c_on.total_usd().to_bits(),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn conservation_holds_mid_run_with_gpus_down_multi_seed() {
        // The tentpole invariant: `completed + failed + in_flight ==
        // arrivals` at every point of a crashing, retrying run —
        // `check_indexes` asserts it (plus the health/retry brute
        // checks) while at least one GPU is verifiably down.
        use crate::sim::fault::{FaultSpec, RetrySpec};
        let cfg = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 150.0,
            mttr_s: 40.0,
            load_fail_prob: 0.1,
            retry: RetrySpec::default(),
            ..FaultSpec::default()
        });
        let mut total_redispatched = 0u64;
        let mut total_retries = 0u64;
        for seed in [1u64, 7, 23] {
            let w = workload(4, 0.1, 600.0, Pattern::Bursty);
            let n = w.requests.len();
            let mut e = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
            let mut steps: u64 = 0;
            let mut checked_down = 0usize;
            while e.step() {
                steps += 1;
                if steps % 5 == 0 || e.cluster.n_down() > 0 {
                    e.check_indexes();
                    if e.cluster.n_down() > 0 {
                        checked_down += 1;
                    }
                }
            }
            e.check_indexes();
            assert!(checked_down > 0, "no mid-run check saw a GPU down (seed {seed})");
            assert!(e.stats.gpu_crashes > 0, "no crashes injected (seed {seed})");
            assert!(e.stats.gpu_recoveries > 0, "no recoveries (seed {seed})");
            let (m, _, st) = e.finish();
            assert_eq!(
                m.outcomes.len() + m.failed as usize,
                n,
                "terminal conservation (seed {seed})"
            );
            assert!(m.goodput() > 0.0 && m.goodput() <= 1.0);
            total_redispatched += st.redispatched;
            total_retries += st.retries;
        }
        assert!(total_redispatched > 0, "crashes never killed an in-flight batch");
        assert!(total_retries > 0, "10% load-fail rate never retried");
    }

    #[test]
    fn dormant_domains_and_degrade_are_bit_identical_too() {
        // PR 9 extension of the dormant lock: a spec that carries the
        // new sub-specs (node + zone domains, degrade, failure-aware
        // routing) but provably never fires must still reproduce the
        // faultless run bit-for-bit — the extra init draws, the health
        // second dimension, the exec rate field, and the router's
        // `score - failure_penalty` (exactly 0.0) all cost zero
        // perturbation.
        use crate::sim::fault::{DegradeSpec, DomainLevel, DomainSpec, FaultSpec};
        let w = workload(4, 0.05, 1800.0, Pattern::Bursty);
        let (m_off, c_off, _) = run(SystemConfig::serverless_lora(), w.clone());
        let dormant = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 1e15,
            load_fail_prob: 0.0,
            domains: Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s: 1e15, mttr_s: 10.0 }),
                zone: Some(DomainLevel { mtbf_s: 1e15, mttr_s: 10.0 }),
            }),
            degrade: Some(DegradeSpec { mtbf_s: 1e15, ..DegradeSpec::default() }),
            failure_aware: true,
            ..FaultSpec::default()
        });
        let (m_on, c_on, st) = run(dormant, w);
        assert_eq!(st.gpu_crashes + st.node_outages + st.zone_outages, 0);
        assert_eq!(st.degrades, 0, "dormant degrade must never fire");
        assert_eq!(m_off.outcomes.len(), m_on.outcomes.len());
        for (a, b) in m_off.outcomes.iter().zip(&m_on.outcomes) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.ttft_s.to_bits(), b.ttft_s.to_bits(), "request {}", a.id);
            assert_eq!(a.e2e_s.to_bits(), b.e2e_s.to_bits(), "request {}", a.id);
        }
        assert_eq!(c_off.total_usd().to_bits(), c_on.total_usd().to_bits());
    }

    #[test]
    fn conservation_holds_mid_run_with_node_and_zone_outages() {
        // The tentpole invariant under correlated domains: conservation
        // (asserted inside check_indexes) holds at every step while
        // whole nodes — and at times the whole zone — are down, and
        // every outage is eventually repaired.
        use crate::sim::fault::{DomainLevel, DomainSpec, FaultSpec};
        let cfg = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 1e12, // isolate the domain levels
            load_fail_prob: 0.0,
            domains: Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s: 200.0, mttr_s: 30.0 }),
                zone: Some(DomainLevel { mtbf_s: 400.0, mttr_s: 25.0 }),
            }),
            ..FaultSpec::default()
        });
        let mut saw_node_down = false;
        let mut saw_all_down = false;
        for seed in [1u64, 7, 23] {
            let w = workload(4, 0.1, 600.0, Pattern::Bursty);
            let n = w.requests.len();
            let mut e = Engine::new(cfg.clone(), Cluster::new(2, 2, 4), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                if steps % 5 == 0 || e.cluster.n_nodes_down() > 0 {
                    e.check_indexes();
                    saw_node_down |= e.cluster.n_nodes_down() > 0;
                    saw_all_down |= e.cluster.n_nodes_down() == 2;
                }
            }
            e.check_indexes();
            assert_eq!(e.cluster.n_nodes_down(), 0, "unrepaired node (seed {seed})");
            assert_eq!(
                e.stats.node_repairs, e.stats.node_outages,
                "node outages and repairs must pair (seed {seed})"
            );
            assert_eq!(e.stats.zone_repairs, e.stats.zone_outages, "seed {seed}");
            let (m, _, st) = e.finish();
            assert!(st.node_outages + st.zone_outages > 0, "nothing fired (seed {seed})");
            assert_eq!(m.outcomes.len() + m.failed as usize, n, "seed {seed}");
        }
        assert!(saw_node_down, "no mid-run check saw a node down");
        assert!(saw_all_down, "no mid-run check saw the whole zone down");
    }

    #[test]
    fn node_outage_wipes_host_cache_once_and_kills_members() {
        // A node outage must behave like the ISSUE says: member batches
        // die, the node's checkpoint cache is wiped once (cache_evictions
        // counts checkpoints, not GPUs × checkpoints), and the fleet
        // keeps conserving requests.
        use crate::sim::fault::{DomainLevel, DomainSpec, FaultSpec};
        let cfg = SystemConfig::serverless_lora()
            .with_tiers(TierSpec::default())
            .with_faults(FaultSpec {
                mtbf_s: 1e12,
                load_fail_prob: 0.0,
                domains: Some(DomainSpec {
                    node: Some(DomainLevel { mtbf_s: 150.0, mttr_s: 20.0 }),
                    zone: None,
                }),
                ..FaultSpec::default()
            });
        let w = workload(4, 0.1, 600.0, Pattern::Bursty);
        let n = w.requests.len();
        let mut e = Engine::new(cfg, Cluster::new(2, 2, 4), w, 7);
        let mut steps: u64 = 0;
        while e.step() {
            steps += 1;
            if steps % 7 == 0 {
                e.check_indexes();
            }
        }
        e.check_indexes();
        assert!(e.stats.node_outages > 0, "no node outage fired");
        assert_eq!(e.stats.gpu_crashes, 0, "GPU-level crashes were isolated off");
        let (m, _, st) = e.finish();
        assert!(st.redispatched > 0, "outages never killed an in-flight batch");
        assert_eq!(m.outcomes.len() + m.failed as usize, n);
    }

    #[test]
    fn degrade_slows_ttft_and_restores() {
        // Degraded mode end-to-end: episodes fire and restore, re-times
        // are counted, conservation holds, and a heavily-degraded fleet
        // is visibly slower than the fault-free one while completing the
        // same request set (degraded ≠ down: nothing is killed).
        use crate::sim::fault::{DegradeSpec, FaultSpec};
        let w = workload(4, 0.1, 600.0, Pattern::Bursty);
        let n = w.requests.len();
        let (m_ref, _, _) = run(SystemConfig::serverless_lora(), w.clone());
        let cfg = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 1e12,
            load_fail_prob: 0.0,
            degrade: Some(DegradeSpec {
                mtbf_s: 120.0,
                duration_s: 60.0,
                factor_min: 3.0,
                factor_max: 6.0,
            }),
            ..FaultSpec::default()
        });
        let mut e = Engine::new(cfg, Cluster::new(1, 2, 4), w, 1);
        let mut steps: u64 = 0;
        let mut saw_degraded = false;
        while e.step() {
            steps += 1;
            if steps % 5 == 0 {
                e.check_indexes();
                saw_degraded |= e.degrade_factor.iter().any(|&k| k != 1.0);
            }
        }
        e.check_indexes();
        assert!(saw_degraded, "no mid-run check saw a degraded GPU");
        assert!(e.stats.degrades > 0, "no degrade episode fired");
        assert_eq!(
            e.stats.degrade_restores, e.stats.degrades,
            "every episode must restore (none was cut short by a crash here)"
        );
        assert!(e.stats.degrade_retimes > 0, "no in-flight work was re-timed");
        assert_eq!(e.stats.requests_failed, 0, "degraded GPUs must not fail requests");
        let (m, _, _) = e.finish();
        assert_eq!(m.outcomes.len(), n, "degraded ≠ down: all requests complete");
        assert!(
            m.ttft().mean > m_ref.ttft().mean,
            "3-6× slowdown episodes must stretch mean TTFT: {} vs {}",
            m.ttft().mean,
            m_ref.ttft().mean
        );
    }

    #[test]
    fn indexes_match_bruteforce_mid_run_multi_seed() {
        // The incremental dispatch-state indexes (per-GPU busy counts,
        // per-function in-flight counts, the active set, the blocked
        // map, the single armed keep-alive check) must equal their
        // brute-force recomputation at every point of the run. NDO uses
        // the blocking offload policy, so the blocked map is exercised.
        for cfg in [SystemConfig::serverless_lora(), SystemConfig::ndo()] {
            for seed in [1u64, 7, 23] {
                let w = workload(4, 0.1, 600.0, Pattern::Bursty);
                let n = w.requests.len();
                let mut e = Engine::new(cfg.clone(), Cluster::new(1, 2, 4), w, seed);
                let mut steps: u64 = 0;
                while e.step() {
                    steps += 1;
                    if steps % 5 == 0 {
                        e.check_indexes();
                    }
                }
                e.check_indexes();
                let (m, _, _) = e.finish();
                assert_eq!(m.outcomes.len(), n, "{} lost requests", cfg.name);
            }
        }
    }
}
