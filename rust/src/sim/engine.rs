//! Discrete-event serving simulator.
//!
//! Drives one `SystemConfig` (ServerlessLoRA, an ablation, or a baseline)
//! over a trace on the simulated cluster: arrivals → batching → routing →
//! artifact loading → prefill → decode, with processor-sharing GPU
//! contention (Eq. 4), strict memory ledgers, keep-alive, dynamic
//! offloading, and event-integrated billing.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap};

use crate::artifact::{params, ArtifactKind, FunctionSpec};
use crate::cluster::{Cluster, GpuId};
use crate::coordinator::{
    BatchQueue, DynamicOffloader, FunctionDemand, KeepAlive, PreloadScheduler,
    Queued, Router,
};
use crate::cost::CostTracker;
use crate::metrics::{Phase, RequestOutcome, RunMetrics};
use crate::sharing::BackboneRegistry;
use crate::sim::config::{BatchingMode, PreloadMode, SystemConfig};
use crate::sim::exec::GpuExec;
use crate::trace::Request;
use crate::util::rng::Pcg64;

/// A workload: functions + merged time-ordered request stream.
#[derive(Debug, Clone)]
pub struct Workload {
    pub functions: Vec<FunctionSpec>,
    pub requests: Vec<Request>,
    pub duration_s: f64,
    /// Mean arrival rate per function (pre-loading benefit input, §4.1).
    pub rates: Vec<f64>,
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum BatchState {
    Loading,
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
struct Batch {
    function: usize,
    gpu: GpuId,
    requests: Vec<Request>,
    load_phases: BTreeMap<Phase, f64>,
    t_dispatch: f64,
    t_exec_start: f64,
    prefill_wall: f64,
    state: BatchState,
    /// Reserved KV GB (kept for observability / debug assertions).
    #[allow(dead_code)]
    kv_gb: f64,
    attached_backbone: bool,
}

#[derive(Debug, Clone, PartialEq)]
enum EventKind {
    Arrival(usize),
    QueueCheck(usize),
    LoadDone(u64),
    GpuTick(GpuId, u64),
    KeepaliveCheck,
}

#[derive(Debug, Clone, PartialEq)]
struct Event {
    t: f64,
    seq: u64,
    kind: EventKind,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Extra run statistics beyond per-request metrics.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    pub offload_events: usize,
    pub offloaded_gb: f64,
    pub preload_decisions: usize,
    pub blocked_dispatches: usize,
    pub cold_dispatches: usize,
    pub warm_dispatches: usize,
}

pub struct Engine {
    cfg: SystemConfig,
    cluster: Cluster,
    registry: BackboneRegistry,
    keepalive: KeepAlive,
    functions: Vec<FunctionSpec>,
    rates: Vec<f64>,
    queues: Vec<BatchQueue>,
    /// Fixed-mode per-function dispatch params (None ⇒ adaptive).
    fixed: Option<(usize, f64)>,
    execs: BTreeMap<GpuId, GpuExec>,
    events: BinaryHeap<Reverse<Event>>,
    seq: u64,
    now: f64,
    batches: BTreeMap<u64, Batch>,
    next_batch: u64,
    /// Functions blocked on GPU memory (NDO): retried on completions.
    blocked: Vec<usize>,
    rng: Pcg64,
    pub metrics: RunMetrics,
    pub cost: CostTracker,
    pub stats: RunStats,
    last_bill_t: f64,
    /// Serverful: function → dedicated GPU.
    dedicated: BTreeMap<usize, GpuId>,
    requests: Vec<Request>,
    /// request id → index in `requests` (dispatch-path lookup).
    request_index: std::collections::HashMap<u64, usize>,
    duration_s: f64,
}

impl Engine {
    pub fn new(cfg: SystemConfig, cluster: Cluster, workload: Workload, seed: u64) -> Self {
        let queues = workload
            .functions
            .iter()
            .map(|f| BatchQueue::new(f.id, &f.model))
            .collect();
        let fixed = match cfg.batching {
            BatchingMode::Adaptive => None,
            BatchingMode::Fixed { size, delay_s } => Some((size, delay_s)),
        };
        let execs = cluster.gpu_ids().into_iter().map(|g| (g, GpuExec::default())).collect();
        let mut e = Engine {
            keepalive: KeepAlive::new(cfg.keepalive_s.min(1e12)),
            cfg,
            cluster,
            registry: BackboneRegistry::new(),
            functions: workload.functions,
            rates: workload.rates,
            queues,
            fixed,
            execs,
            events: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
            batches: BTreeMap::new(),
            next_batch: 1,
            blocked: Vec::new(),
            rng: Pcg64::with_stream(seed, 0x51f7),
            metrics: RunMetrics::default(),
            cost: CostTracker::default(),
            stats: RunStats::default(),
            last_bill_t: 0.0,
            dedicated: BTreeMap::new(),
            request_index: workload
                .requests
                .iter()
                .enumerate()
                .map(|(i, r)| (r.id, i))
                .collect(),
            requests: workload.requests,
            duration_s: workload.duration_s,
        };
        e.metrics.duration_s = e.duration_s;
        e.setup();
        e
    }

    fn push_event(&mut self, t: f64, kind: EventKind) {
        self.seq += 1;
        self.events.push(Reverse(Event { t, seq: self.seq, kind }));
    }

    fn spec(&self, f: usize) -> &FunctionSpec {
        &self.functions[f]
    }

    // ------------------------------------------------------------- setup

    fn setup(&mut self) {
        for i in 0..self.requests.len() {
            let t = self.requests[i].arrival_s;
            self.push_event(t, EventKind::Arrival(i));
        }
        if self.cfg.serverful {
            self.setup_serverful();
        } else if self.cfg.preload == PreloadMode::Full {
            self.run_preloader();
        } else if let PreloadMode::ContainerOpportunistic { .. } = self.cfg.preload {
            self.setup_instainfer_containers();
        }
    }

    /// Serverful: dedicate GPUs and make everything resident up-front.
    /// vLLM: one deployment per function. dLoRA: one per backbone model
    /// (its adapters share the backbone in-process).
    fn setup_serverful(&mut self) {
        let gpu_ids = self.cluster.gpu_ids();
        if self.cfg.backbone_sharing {
            // dLoRA: GPU per distinct model.
            let mut model_gpu: BTreeMap<&str, GpuId> = BTreeMap::new();
            let mut next = 0;
            let specs: Vec<(usize, &'static str, f64, f64, f64)> = self
                .functions
                .iter()
                .map(|f| {
                    (f.id, f.model.name, f.model.weights_gb, f.model.adapter_gb, f.model.kernel_gb)
                })
                .collect();
            for (id, model, wgb, agb, kgb) in specs {
                let g = *model_gpu.entry(model).or_insert_with(|| {
                    let g = gpu_ids[next % gpu_ids.len()];
                    next += 1;
                    g
                });
                self.registry.load(&mut self.cluster, model, wgb, g).unwrap();
                let gpu = self.cluster.gpu_mut(g);
                gpu.place_artifact(id, ArtifactKind::Adapter, agb).unwrap();
                gpu.place_artifact(id, ArtifactKind::CudaKernel, kgb).unwrap();
                gpu.create_cuda_context(id).unwrap();
                self.dedicated.insert(id, g);
            }
        } else {
            // vLLM: GPU per function, private backbone.
            let specs: Vec<(usize, f64, f64, f64)> = self
                .functions
                .iter()
                .map(|f| (f.id, f.model.weights_gb, f.model.adapter_gb, f.model.kernel_gb))
                .collect();
            for (i, (id, wgb, agb, kgb)) in specs.into_iter().enumerate() {
                let g = gpu_ids[i % gpu_ids.len()];
                let gpu = self.cluster.gpu_mut(g);
                gpu.place_artifact(id, ArtifactKind::Backbone, wgb).unwrap();
                gpu.place_artifact(id, ArtifactKind::Adapter, agb).unwrap();
                gpu.place_artifact(id, ArtifactKind::CudaKernel, kgb).unwrap();
                gpu.create_cuda_context(id).unwrap();
                self.dedicated.insert(id, g);
            }
        }
    }

    /// §4.1 pre-loading at deployment time (Full mode). Also pre-warms
    /// CUDA contexts on the chosen GPUs (the Agent's pre-warming duty).
    fn run_preloader(&mut self) {
        let demands: Vec<FunctionDemand> = self
            .functions
            .iter()
            .zip(&self.rates)
            .map(|(spec, &rate)| FunctionDemand { spec: spec.clone(), rate })
            .collect();
        let sched = PreloadScheduler::default();
        let plan = sched.plan(&demands, &self.cluster, &self.registry);
        if self.cfg.backbone_sharing {
            sched.apply(&plan, &demands, &mut self.cluster, &mut self.registry);
        } else {
            // NBS ablation: the same plan, but every function pays for a
            // *private* backbone copy (best-effort under memory).
            for d in &plan.decisions {
                let spec = &self.functions[d.function];
                match (d.kind, d.placement) {
                    (ArtifactKind::Backbone, crate::coordinator::Placement::Gpu(g)) => {
                        let _ = self.cluster.gpu_mut(g).place_artifact(
                            d.function,
                            ArtifactKind::Backbone,
                            spec.model.weights_gb,
                        );
                    }
                    (k, crate::coordinator::Placement::Gpu(g)) => {
                        let _ = self.cluster.gpu_mut(g).place_artifact(
                            d.function, k, d.size_gb,
                        );
                    }
                    (k, crate::coordinator::Placement::Container(cid)) => {
                        let _ = self.cluster.container_mut(cid).place(
                            d.function, k, d.size_gb,
                        );
                    }
                }
            }
        }
        self.stats.preload_decisions = plan.decisions.len();
        // Staging copies: one container copy of each model's backbone so
        // on-demand *replicas* (contention relief) load over PCIe rather
        // than from SSD. Host RAM is cheap; the PCKP plan covered the
        // GPU-side placements.
        let models: Vec<(usize, &'static str, f64)> = self
            .functions
            .iter()
            .map(|s| (s.id, s.model.name, s.model.weights_gb))
            .collect();
        let mut staged: std::collections::BTreeSet<&str> = Default::default();
        let cids = self.cluster.container_ids();
        for (i, (fid, model, wgb)) in models.into_iter().enumerate() {
            if staged.insert(model) {
                let cid = cids[i % cids.len()];
                let _ = self
                    .cluster
                    .container_mut(cid)
                    .place(fid, ArtifactKind::Backbone, wgb);
            }
        }
        // Pre-warm the process (CUDA context) where the kernel landed.
        let kernel_sites: Vec<(usize, GpuId)> = plan
            .decisions
            .iter()
            .filter_map(|d| match (d.kind, d.placement) {
                (ArtifactKind::CudaKernel, crate::coordinator::Placement::Gpu(g)) => {
                    Some((d.function, g))
                }
                _ => None,
            })
            .collect();
        for (f, g) in kernel_sites {
            let _ = self.cluster.gpu_mut(g).create_cuda_context(f);
        }
    }

    /// InstaInfer: libraries + backbone + adapter into idle containers'
    /// RAM (one function per container slot, round-robin).
    fn setup_instainfer_containers(&mut self) {
        let cids = self.cluster.container_ids();
        let specs: Vec<(usize, f64, f64, f64)> = self
            .functions
            .iter()
            .map(|f| (f.id, f.model.library_gb, f.model.weights_gb, f.model.adapter_gb))
            .collect();
        for (i, (id, lib, w, a)) in specs.into_iter().enumerate() {
            let cid = cids[i % cids.len()];
            let c = self.cluster.container_mut(cid);
            let _ = c.place(id, ArtifactKind::Library, lib);
            let _ = c.place(id, ArtifactKind::Backbone, w);
            let _ = c.place(id, ArtifactKind::Adapter, a);
        }
    }

    // -------------------------------------------------------------- run

    pub fn run(mut self) -> (RunMetrics, CostTracker, RunStats) {
        while let Some(Reverse(ev)) = self.events.pop() {
            debug_assert!(ev.t >= self.now - 1e-6, "time went backwards");
            self.bill_interval(ev.t);
            self.now = ev.t;
            match ev.kind {
                EventKind::Arrival(i) => self.on_arrival(i),
                EventKind::QueueCheck(f) => self.try_dispatch_all(Some(f)),
                EventKind::LoadDone(b) => self.on_load_done(b),
                EventKind::GpuTick(g, v) => self.on_gpu_tick(g, v),
                EventKind::KeepaliveCheck => self.on_keepalive(),
            }
        }
        // Final billing to the end of the workload window.
        let end = self.duration_s.max(self.now);
        self.bill_interval(end);
        if self.cfg.serverful {
            let n: std::collections::BTreeSet<GpuId> =
                self.dedicated.values().cloned().collect();
            self.cost.add_serverful(n.len() as f64, end);
        }
        // Throughput denominators use the makespan (last completion),
        // not the arrival window — saturating workloads drain past it.
        let makespan = self
            .metrics
            .outcomes
            .iter()
            .map(|o| o.arrival_s + o.e2e_s)
            .fold(self.duration_s, f64::max);
        self.metrics.duration_s = makespan;
        (self.metrics, self.cost, self.stats)
    }

    /// Event-integrated billing (serverless only): between events every
    /// GPU bills its resident GB at the active rate while it has work,
    /// else at the keep-alive idle rate.
    fn bill_interval(&mut self, until: f64) {
        let dt = until - self.last_bill_t;
        if dt <= 0.0 || self.cfg.serverful {
            self.last_bill_t = until.max(self.last_bill_t);
            return;
        }
        let mut loading_gpus: BTreeMap<GpuId, usize> = BTreeMap::new();
        for b in self.batches.values() {
            if b.state == BatchState::Loading {
                *loading_gpus.entry(b.gpu).or_insert(0) += 1;
            }
        }
        for g in self.cluster.gpu_ids() {
            let gpu = self.cluster.gpu(g);
            let used = gpu.used_gb() - params::GPU_RESERVED_GB;
            if used <= 0.0 {
                continue;
            }
            // Without backbone sharing a function occupies its GPU
            // *exclusively* (§1 Observation: "exclusive GPU occupation") —
            // serverless platforms bill the whole allocated GPU, not the
            // bytes actually touched. Sharing is what enables fractional
            // allocation (and is where the cost win comes from).
            let billed = if self.cfg.backbone_sharing {
                used
            } else {
                gpu.total_gb
            };
            let active = self.execs[&g].is_active() || loading_gpus.contains_key(&g);
            if active {
                // CPU/host-mem of the functions actively executing there.
                self.cost.add_active(billed, dt, 4.0, 16.0);
            } else {
                // Idle (keep-alive) billing applies to *user instances*
                // kept warm after an invocation. Artifacts staged by the
                // Pre-Loading Agent in the provider's idle pool are not
                // billed to the user (§2.4: "pre-loading without extra
                // wastage") — so idle GB-s accrue only while some
                // keep-alive-warm function resides on this GPU.
                let warm_resident = self
                    .cluster
                    .gpu(g)
                    .resident_functions()
                    .iter()
                    .any(|&f| self.keepalive.is_warm(f, self.last_bill_t));
                if warm_resident {
                    self.cost.add_idle(billed, dt, 4.0);
                }
            }
        }
        self.last_bill_t = until;
    }

    // ---------------------------------------------------------- arrivals

    fn on_arrival(&mut self, i: usize) {
        let req = self.requests[i].clone();
        let f = req.function;
        self.queues[f].push(Queued { request: req.id, arrival_s: req.arrival_s });
        self.try_dispatch_all(Some(f));
        // Wakeups: debounce settle-point and the Eq. 3 expiry.
        if !self.queues[f].is_empty() {
            self.push_event(
                self.now + crate::coordinator::batching::DEBOUNCE_S + 1e-3,
                EventKind::QueueCheck(f),
            );
        }
        if let Some(t) = self.queue_expiry(f) {
            if t.is_finite() && t > self.now {
                self.push_event(t, EventKind::QueueCheck(f));
            }
        }
    }

    fn queue_expiry(&self, f: usize) -> Option<f64> {
        match self.fixed {
            None => self.queues[f].expiry_time(),
            Some((_, delay)) => self.queues[f].oldest_arrival().map(|a| a + delay),
        }
    }

    fn should_dispatch(&self, f: usize) -> bool {
        let q = &self.queues[f];
        if q.is_empty() {
            return false;
        }
        match self.fixed {
            // Adaptive (§4.2): fire when full or expired — or once the
            // arrival stream settles (debounce) and the target GPU has a
            // free prefill slot. Waiting longer only buys anything under
            // contention (Eq. 4/5); on a free GPU with a settled queue,
            // serving now strictly dominates.
            None => {
                q.should_dispatch(self.now)
                    || (q.settled(self.now) && self.target_gpu_idle(f))
            }
            Some((size, delay)) => {
                q.len() >= size
                    || self.now - q.oldest_arrival().unwrap() >= delay - 1e-9
            }
        }
    }

    /// Is the GPU this function would route to free to take a prefill now?
    /// Decode-phase jobs do not defer dispatch (decode is memory-bound and
    /// overlaps an incoming prefill well — the reason iteration-level
    /// batching works); loading batches and prefill-phase batches do.
    fn target_gpu_idle(&self, f: usize) -> bool {
        let gpu = match self.dedicated.get(&f) {
            Some(&g) => Some(g),
            None => Router::route(&self.cluster, &self.registry, self.spec(f), 1)
                .map(|r| r.gpu),
        };
        let Some(g) = gpu else { return false };
        !self.batches.values().any(|b| {
            b.gpu == g && matches!(b.state, BatchState::Loading | BatchState::Prefill)
        })
    }

    /// Global dispatch loop: repeatedly pick the dispatchable queue with
    /// the tightest Eq. 5 deadline margin and dispatch it.
    ///
    /// With a `hint`, only that function is considered — an arrival can
    /// only change its own queue's dispatchability (GPU state is
    /// untouched), so scanning all queues on every arrival would be
    /// wasted work. Completion/offload events pass `None` for the full
    /// margin-ordered scan.
    fn try_dispatch_all(&mut self, hint: Option<usize>) {
        if let Some(f) = hint {
            while self.should_dispatch(f)
                && !self.blocked.contains(&f)
                && self.dispatch(f)
            {}
            if self.should_dispatch(f) && !self.blocked.contains(&f) {
                self.blocked.push(f);
                self.stats.blocked_dispatches += 1;
            }
            return;
        }
        loop {
            let mut ready: Vec<usize> = (0..self.queues.len())
                .filter(|&f| self.should_dispatch(f) && !self.blocked.contains(&f))
                .collect();
            if ready.is_empty() {
                return;
            }
            // Eq. 5 prioritisation (adaptive mode only; fixed mode FIFO).
            if self.fixed.is_none() {
                ready.sort_by(|&a, &b| {
                    let ma = self.margin(a);
                    let mb = self.margin(b);
                    ma.partial_cmp(&mb).unwrap()
                });
            }
            let f = ready[0];
            if !self.dispatch(f) {
                self.blocked.push(f);
                self.stats.blocked_dispatches += 1;
            }
        }
    }

    fn margin(&self, f: usize) -> f64 {
        let gpu_hint = self
            .dedicated
            .get(&f)
            .copied()
            .or_else(|| self.registry.hosts(self.spec(f).model.name).first().copied());
        let m = gpu_hint
            .map(|g| self.execs[&g].contention() + 1)
            .unwrap_or(1);
        self.queues[f].deadline_margin(self.now, m)
    }

    // ---------------------------------------------------------- dispatch

    /// Dispatch one batch for function `f`. Returns false when blocked on
    /// GPU memory (NDO mode waits; dynamic offloading avoids this).
    fn dispatch(&mut self, f: usize) -> bool {
        let spec = self.spec(f).clone();
        let gpu = match self.dedicated.get(&f) {
            Some(&g) => g,
            None => match Router::route(&self.cluster, &self.registry, &spec, 1) {
                Some(r) => self.maybe_replicate(&spec, r.gpu),
                None => return false,
            },
        };

        // Desired batch under the SLO bound (Eq. 2) / fixed size.
        let queued = self.queues[f].len();
        let want = match self.fixed {
            None => queued.min(self.queues[f].max_batch),
            Some((size, _)) => queued.min(size),
        }
        .max(1);

        // Memory needed: KV for the batch + any artifacts still missing.
        let readiness = Router::readiness(&self.cluster, &spec, gpu);
        let mut need_gb = spec.model.kv_per_request_gb * want as f64;
        if !readiness.backbone_on_gpu {
            need_gb += spec.model.weights_gb;
        }
        if !readiness.adapter_on_gpu {
            need_gb += spec.model.adapter_gb;
        }
        if !readiness.kernel_on_gpu {
            need_gb += spec.model.kernel_gb;
        }
        if !readiness.cuda_context {
            need_gb += params::CUDA_CONTEXT_GB;
        }

        if self.cluster.gpu(gpu).free_gb() < need_gb {
            if self.cfg.dynamic_offload {
                // §4.3: free Q_g by evicting the least-valuable unrelated
                // artifacts. Value = reload latency × that fn's rate.
                let rates = self.rates.clone();
                let functions = self.functions.clone();
                let spill = self.cluster_spill_target(gpu);
                let plan = DynamicOffloader::free(
                    &mut self.cluster,
                    &mut self.registry,
                    gpu,
                    need_gb,
                    &[f],
                    |of, kind| {
                        let rate = of.map(|x| rates[x]).unwrap_or(0.05);
                        let reload = match kind {
                            ArtifactKind::Backbone => of
                                .map(|x| functions[x].model.weights_gb / params::BW_SSD_GBPS)
                                .unwrap_or(3.0),
                            ArtifactKind::Adapter => 0.3,
                            ArtifactKind::CudaKernel => 2.5,
                            _ => 0.5,
                        };
                        reload * rate
                    },
                    spill,
                );
                self.stats.offload_events += 1;
                self.stats.offloaded_gb += plan.freed_gb;
                if self.cluster.gpu(gpu).free_gb() < need_gb {
                    // Even full eviction can't fit: shrink the batch.
                    let kv_free = self.cluster.gpu(gpu).free_gb()
                        - (need_gb - spec.model.kv_per_request_gb * want as f64);
                    let fit = (kv_free / spec.model.kv_per_request_gb).floor() as i64;
                    if fit < 1 {
                        return false;
                    }
                }
            } else {
                // NDO / baselines: block until completions free memory.
                let kv_free = self.cluster.gpu(gpu).free_gb()
                    - (need_gb - spec.model.kv_per_request_gb * want as f64);
                if (kv_free / spec.model.kv_per_request_gb).floor() < 1.0 {
                    return false;
                }
            }
        }

        // Final batch size bounded by what actually fits.
        let fixed_gb = need_gb - spec.model.kv_per_request_gb * want as f64;
        let kv_budget = self.cluster.gpu(gpu).free_gb() - fixed_gb;
        let cap = (kv_budget / spec.model.kv_per_request_gb).floor().max(0.0) as usize;
        if cap == 0 {
            return false;
        }
        let taken = self.queues[f].take_batch(cap.min(want));
        debug_assert!(!taken.is_empty());
        let reqs: Vec<Request> = taken
            .iter()
            .map(|q| self.requests[self.request_index[&q.request]].clone())
            .collect();
        let b = reqs.len();

        // Mutate ledgers: make everything resident, reserve KV.
        let batch_id = self.next_batch;
        self.next_batch += 1;
        let load_phases = self.make_resident(f, &spec, gpu, readiness);
        let kv_gb = spec.model.kv_per_request_gb * b as f64;
        self.cluster
            .gpu_mut(gpu)
            .reserve_kv(batch_id, kv_gb)
            .expect("kv sized to fit");
        let attached = if self.cfg.backbone_sharing {
            self.registry
                .attach(&mut self.cluster, spec.model.name, gpu, f)
                .is_ok()
        } else {
            false
        };

        // §4.2: batching "avoid[s] creating new instances". A dispatch
        // while this function already has in-flight batches forces the
        // platform to scale out a NEW process instance: it pays its own
        // CUDA context plus per-context kernel handles (contexts are
        // per-process; pre-loaded artifacts shortcut the JIT but not the
        // context). This is what makes no-batching (NAB#1) slow under
        // concurrency even when everything is pre-loaded.
        let mut load_phases = load_phases;
        let concurrent = self.batches.values().any(|b| b.function == f);
        if concurrent && !self.cfg.serverful {
            *load_phases.entry(Phase::ContainerInit).or_insert(0.0) +=
                params::CUDA_CONTEXT_INIT_S;
            let kernel_warm = self.cfg.preload == PreloadMode::Full;
            *load_phases.entry(Phase::KernelCompile).or_insert(0.0) += if kernel_warm {
                spec.model.kernel_cache_load_s
            } else {
                spec.model.kernel_jit_s
            };
        }

        let total_load: f64 = load_phases.values().sum();
        if total_load > 0.0 {
            self.stats.cold_dispatches += 1;
        } else {
            self.stats.warm_dispatches += 1;
        }
        self.batches.insert(
            batch_id,
            Batch {
                function: f,
                gpu,
                requests: reqs,
                load_phases,
                t_dispatch: self.now,
                t_exec_start: 0.0,
                prefill_wall: 0.0,
                state: BatchState::Loading,
                kv_gb,
                attached_backbone: attached,
            },
        );
        self.push_event(self.now + total_load, EventKind::LoadDone(batch_id));
        true
    }

    /// Locality-vs-contention trade (§3.1 challenge 3): the router prefers
    /// GPUs that already host the backbone, but when every host is
    /// congested and a colder GPU has room for another shared copy, pay
    /// the one-time replica load — all later functions of this model
    /// attach to it for free.
    fn maybe_replicate(&self, spec: &FunctionSpec, routed: GpuId) -> GpuId {
        if !self.cfg.backbone_sharing {
            return routed;
        }
        let contention = self.execs[&routed].contention();
        if contention < 2 {
            return routed;
        }
        let need = spec.model.gpu_resident_gb() + spec.model.kv_per_request_gb;
        self.cluster
            .gpu_ids()
            .into_iter()
            .filter(|&g| {
                self.execs[&g].contention() == 0 && self.cluster.gpu(g).free_gb() >= need
            })
            .max_by(|&a, &b| {
                self.cluster
                    .gpu(a)
                    .free_gb()
                    .partial_cmp(&self.cluster.gpu(b).free_gb())
                    .unwrap()
            })
            .unwrap_or(routed)
    }

    fn cluster_spill_target(&self, gpu: GpuId) -> Option<crate::cluster::ContainerId> {
        self.cluster
            .nodes
            .get(gpu.node)
            .and_then(|n| n.containers.first())
            .map(|c| c.id)
    }

    /// Make all artifacts of `f` resident on `gpu`, returning the phase →
    /// latency map for whatever had to be loaded (§6.3 breakdown).
    fn make_resident(
        &mut self,
        f: usize,
        spec: &FunctionSpec,
        gpu: GpuId,
        ready: crate::coordinator::Readiness,
    ) -> BTreeMap<Phase, f64> {
        let mut phases = BTreeMap::new();
        if self.cfg.serverful {
            return phases; // always resident
        }
        let m = &spec.model;
        // A pre-warmed instance (Full pre-loading: kernels compiled +
        // CUDA context created by the Pre-Loading Agent) is as good as a
        // keep-alive-warm one — this is exactly the §6.3 claim that fully
        // pre-loaded cold starts run at warm-start speed.
        let prewarmed = self.cfg.preload == PreloadMode::Full
            && ready.cuda_context
            && ready.kernel_on_gpu;
        let warm_instance =
            prewarmed || (self.keepalive.is_warm(f, self.now) && ready.cuda_context);
        let container_has = |cl: &Cluster, kind: ArtifactKind| {
            cl.container_ids().iter().any(|&c| cl.container(c).has(f, kind))
        };
        // Backbone staging copies are per-model, not per-function: any
        // function of the same model can read the host-RAM copy.
        let same_model: Vec<usize> = self
            .functions
            .iter()
            .filter(|s| s.model.name == m.name)
            .map(|s| s.id)
            .collect();
        let container_has_backbone = |cl: &Cluster| {
            cl.container_ids().iter().any(|&c| {
                same_model
                    .iter()
                    .any(|&fid| cl.container(c).has(fid, ArtifactKind::Backbone))
            })
        };

        // InstaInfer churn: mispredicted cold start waits for the
        // in-flight preload of *another* function before its own loads.
        let mut insta_hit = true;
        if let PreloadMode::ContainerOpportunistic { hit_rate } = self.cfg.preload {
            if !warm_instance {
                insta_hit = self.rng.f64() < hit_rate;
                if !insta_hit {
                    *phases.entry(Phase::Queue).or_insert(0.0) +=
                        m.weights_gb / params::BW_SSD_GBPS;
                }
            }
        }

        // Container + process (CUDA context) initialisation.
        if !warm_instance && !ready.cuda_context {
            let ctr_cold = matches!(
                self.cfg.preload,
                PreloadMode::None | PreloadMode::FastCheckpoint
            );
            let mut t = params::CUDA_CONTEXT_INIT_S;
            if ctr_cold {
                t += params::CONTAINER_INIT_S;
            }
            phases.insert(Phase::ContainerInit, t);
        }

        // Libraries.
        if !warm_instance {
            let t = match self.cfg.preload {
                PreloadMode::Full => params::LIBRARY_WARM_IMPORT_S,
                PreloadMode::ContainerOpportunistic { .. } => {
                    if insta_hit && container_has(&self.cluster, ArtifactKind::Library) {
                        params::LIBRARY_WARM_IMPORT_S
                    } else {
                        m.library_gb / params::BW_SSD_GBPS + params::LIBRARY_IMPORT_S
                    }
                }
                _ => m.library_gb / params::BW_SSD_GBPS + params::LIBRARY_IMPORT_S,
            };
            phases.insert(Phase::LibraryLoad, t);
        }

        // Backbone.
        if !ready.backbone_on_gpu {
            let t = match self.cfg.preload {
                // ServerlessLLM multi-tier checkpoint store: PCIe speed.
                PreloadMode::FastCheckpoint => m.weights_gb / params::BW_PCIE_GBPS,
                PreloadMode::ContainerOpportunistic { .. } => {
                    if insta_hit && container_has(&self.cluster, ArtifactKind::Backbone) {
                        m.weights_gb / params::BW_PCIE_GBPS
                    } else {
                        m.weights_gb / params::BW_SSD_GBPS
                            + m.weights_gb / params::BW_PCIE_GBPS
                    }
                }
                _ => {
                    if container_has_backbone(&self.cluster) {
                        m.weights_gb / params::BW_PCIE_GBPS
                    } else {
                        m.weights_gb / params::BW_SSD_GBPS
                    }
                }
            };
            phases.insert(Phase::BackboneLoad, t);
            if self.cfg.backbone_sharing {
                self.registry
                    .load(&mut self.cluster, m.name, m.weights_gb, gpu)
                    .expect("sized in dispatch");
            } else {
                self.cluster
                    .gpu_mut(gpu)
                    .place_artifact(f, ArtifactKind::Backbone, m.weights_gb)
                    .expect("sized in dispatch");
            }
        }

        // Adapter.
        if !ready.adapter_on_gpu {
            let t = if container_has(&self.cluster, ArtifactKind::Adapter) {
                m.adapter_gb / params::BW_PCIE_GBPS + params::ADAPTER_ATTACH_S
            } else {
                m.adapter_gb / params::BW_SSD_GBPS + params::ADAPTER_ATTACH_S
            };
            phases.insert(Phase::AdapterLoad, t);
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::Adapter, m.adapter_gb)
                .expect("sized in dispatch");
        }

        // CUDA kernels: JIT on a cold process, unless pre-compiled (Full
        // preload keeps a warm kernel cache even on a replica GPU) or the
        // warm instance still has them.
        if !ready.kernel_on_gpu {
            let t = if warm_instance {
                0.0
            } else if self.cfg.preload == PreloadMode::Full {
                m.kernel_cache_load_s
            } else {
                m.kernel_jit_s
            };
            if t > 0.0 {
                phases.insert(Phase::KernelCompile, t);
            }
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::CudaKernel, m.kernel_gb)
                .expect("sized in dispatch");
        }

        if !ready.cuda_context {
            self.cluster
                .gpu_mut(gpu)
                .create_cuda_context(f)
                .expect("sized in dispatch");
        }
        phases
    }

    // ------------------------------------------------------- exec events

    fn on_load_done(&mut self, batch_id: u64) {
        let (gpu, f, b) = {
            let batch = self.batches.get_mut(&batch_id).expect("batch exists");
            batch.state = BatchState::Prefill;
            batch.t_exec_start = self.now;
            (batch.gpu, batch.function, batch.requests.len())
        };
        let work = self.spec(f).model.prefill_s(b);
        let exec = self.execs.get_mut(&gpu).unwrap();
        exec.add(self.now, batch_id, work);
        self.schedule_tick(gpu);
    }

    fn schedule_tick(&mut self, gpu: GpuId) {
        let exec = &self.execs[&gpu];
        if let Some((_, t)) = exec.next_completion() {
            let v = exec.version;
            self.push_event(t.max(self.now), EventKind::GpuTick(gpu, v));
        }
    }

    fn on_gpu_tick(&mut self, gpu: GpuId, version: u64) {
        if self.execs[&gpu].version != version {
            return; // stale
        }
        let finished = self.execs.get_mut(&gpu).unwrap().finished_at(self.now);
        for id in finished {
            self.on_job_done(id);
        }
        self.schedule_tick(gpu);
    }

    fn on_job_done(&mut self, batch_id: u64) {
        let state = self.batches[&batch_id].state;
        match state {
            BatchState::Prefill => {
                let (gpu, f, b, max_out) = {
                    let batch = self.batches.get_mut(&batch_id).unwrap();
                    batch.prefill_wall = self.now - batch.t_exec_start;
                    batch.state = BatchState::Decode;
                    (
                        batch.gpu,
                        batch.function,
                        batch.requests.len(),
                        batch.requests.iter().map(|r| r.output_tokens).max().unwrap(),
                    )
                };
                let work = self.spec(f).model.tpot_at(b) * max_out as f64;
                let exec = self.execs.get_mut(&gpu).unwrap();
                exec.add_weighted(
                    self.now,
                    batch_id,
                    work,
                    crate::sim::exec::DECODE_WEIGHT,
                );
                self.schedule_tick(gpu);
                // Prefill slot freed: queues waiting on this GPU may go.
                self.try_dispatch_all(None);
            }
            BatchState::Decode => self.finalize_batch(batch_id),
            BatchState::Loading => unreachable!("loading batches are not exec jobs"),
        }
    }

    fn finalize_batch(&mut self, batch_id: u64) {
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let f = batch.function;
        let b = batch.requests.len();
        let decode_start = batch.t_exec_start + batch.prefill_wall;
        let decode_wall = self.now - decode_start;
        let max_out = batch
            .requests
            .iter()
            .map(|r| r.output_tokens)
            .max()
            .unwrap()
            .max(1) as f64;

        for r in &batch.requests {
            let mut phases = batch.load_phases.clone();
            let queue_wait = batch.t_dispatch - r.arrival_s;
            *phases.entry(Phase::Queue).or_insert(0.0) += queue_wait.max(0.0);
            phases.insert(Phase::Prefill, batch.prefill_wall);
            // Requests stop decoding at their own length; wall time scales
            // proportionally under processor sharing.
            let own_decode = decode_wall * r.output_tokens as f64 / max_out;
            phases.insert(Phase::Decode, own_decode);
            let tpot = own_decode / r.output_tokens.max(1) as f64;
            let outcome: RequestOutcome =
                crate::metrics::outcome_from_phases(r, phases, tpot, b);
            self.metrics.record(outcome);
        }

        // Release resources.
        self.cluster.gpu_mut(batch.gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self
                .registry
                .detach(&mut self.cluster, &crate::sharing::IpcHandle {
                    model,
                    gpu: batch.gpu,
                    function: f,
                });
        }
        // Keep-alive (serverless) and wakeup for its expiry.
        if !self.cfg.serverful {
            self.keepalive.touch(f, self.now);
            let t = self.now + self.keepalive.window_s;
            if t.is_finite() {
                self.push_event(t, EventKind::KeepaliveCheck);
            }
        }
        // Memory freed: retry blocked + any dispatchable queues.
        self.blocked.clear();
        self.try_dispatch_all(None);
    }

    fn on_keepalive(&mut self) {
        let expired = self.keepalive.expired(self.now);
        for (f, _) in expired {
            // A function whose window lapsed loses its *instance*. Its
            // artifacts persist only under Full pre-loading (they belong
            // to the Pre-Loading Agent, not the instance).
            if self.cfg.preload == PreloadMode::Full {
                continue;
            }
            let has_batch = self.batches.values().any(|b| b.function == f);
            if has_batch {
                continue; // mid-flight; next completion re-arms keep-alive
            }
            for g in self.cluster.gpu_ids() {
                let gpu = self.cluster.gpu_mut(g);
                let _ = gpu.evict_artifact(f, ArtifactKind::Adapter);
                let _ = gpu.evict_artifact(f, ArtifactKind::CudaKernel);
                let _ = gpu.evict_artifact(f, ArtifactKind::Backbone);
                gpu.destroy_cuda_context(f);
            }
            // Shared backbone: if no warm function of this model remains,
            // drop the idle segment (nobody pays to keep it).
            if self.cfg.backbone_sharing {
                let model = self.spec(f).model.name;
                let still_warm = self.functions.iter().any(|s| {
                    s.model.name == model && self.keepalive.is_warm(s.id, self.now)
                });
                if !still_warm {
                    for g in self.registry.hosts(model).to_vec() {
                        let _ = self.registry.unload(&mut self.cluster, model, g);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::ModelProfile;
    use crate::trace::{Pattern, TraceSpec};

    fn workload(n_fns: usize, rate: f64, dur: f64, pattern: Pattern) -> Workload {
        let functions: Vec<FunctionSpec> = (0..n_fns)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let traces: Vec<Vec<Request>> = (0..n_fns)
            .map(|i| TraceSpec::new(i, pattern, rate, 9 + i as u64).generate(dur))
            .collect();
        Workload {
            functions,
            requests: crate::trace::merge(traces),
            duration_s: dur,
            rates: vec![rate; n_fns],
        }
    }

    fn run(cfg: SystemConfig, w: Workload) -> (RunMetrics, CostTracker, RunStats) {
        Engine::new(cfg, Cluster::new(1, 2, 4), w, 1).run()
    }

    #[test]
    fn conservation_all_requests_served() {
        let w = workload(4, 0.05, 1800.0, Pattern::Normal);
        let n = w.requests.len();
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m.outcomes.len(), n, "arrived == completed");
    }

    #[test]
    fn serverful_has_zero_cold_start() {
        let w = workload(2, 0.05, 900.0, Pattern::Predictable);
        let (m, _, _) = run(SystemConfig::vllm(), w);
        for o in &m.outcomes {
            assert_eq!(o.cold_start_s(), 0.0, "vLLM never cold-starts");
        }
    }

    #[test]
    fn serverless_lora_beats_serverless_llm_on_ttft() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (lora, _, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (sllm, _, _) = run(SystemConfig::serverless_llm(), w);
        assert!(
            lora.ttft().mean < sllm.ttft().mean,
            "lora {} vs sllm {}",
            lora.ttft().mean,
            sllm.ttft().mean
        );
    }

    #[test]
    fn preload_reduces_ttft_vs_npl() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (full, _, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (npl, _, _) = run(SystemConfig::npl(), w);
        assert!(full.ttft().mean <= npl.ttft().mean * 1.01);
    }

    #[test]
    fn sharing_cheaper_than_nbs() {
        let w = workload(4, 0.02, 3600.0, Pattern::Normal);
        let (_, c_full, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (_, c_nbs, _) = run(SystemConfig::nbs(), w);
        assert!(
            c_full.total_usd() < c_nbs.total_usd(),
            "shared {} vs NBS {}",
            c_full.total_usd(),
            c_nbs.total_usd()
        );
    }

    #[test]
    fn serverless_cost_scales_with_usage_not_walltime() {
        // Half the request rate ⇒ materially cheaper (pay-per-use);
        // serverful cost identical.
        let w1 = workload(2, 0.04, 3600.0, Pattern::Normal);
        let w2 = workload(2, 0.01, 3600.0, Pattern::Normal);
        let (_, c1, _) = run(SystemConfig::serverless_lora(), w1.clone());
        let (_, c2, _) = run(SystemConfig::serverless_lora(), w2.clone());
        assert!(c2.total_usd() < c1.total_usd());
        let (_, v1, _) = run(SystemConfig::vllm(), w1);
        let (_, v2, _) = run(SystemConfig::vllm(), w2);
        assert!((v1.total_usd() - v2.total_usd()).abs() / v1.total_usd() < 0.05);
    }

    #[test]
    fn bursty_benefits_from_batching() {
        // Adaptive batching must produce batches > 1 under bursts.
        let w = workload(2, 0.2, 1800.0, Pattern::Bursty);
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        assert!(m.peak_batch() > 1, "peak batch {}", m.peak_batch());
    }

    #[test]
    fn ttfts_nonnegative_and_ordered() {
        let w = workload(4, 0.05, 900.0, Pattern::Bursty);
        let (m, _, _) = run(SystemConfig::serverless_lora(), w);
        for o in &m.outcomes {
            assert!(o.ttft_s >= 0.0);
            assert!(o.e2e_s >= o.ttft_s);
            assert!(o.tpot_s >= 0.0);
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let w = workload(3, 0.05, 900.0, Pattern::Normal);
        let (m1, c1, _) = run(SystemConfig::serverless_lora(), w.clone());
        let (m2, c2, _) = run(SystemConfig::serverless_lora(), w);
        assert_eq!(m1.outcomes.len(), m2.outcomes.len());
        assert!((m1.ttft().mean - m2.ttft().mean).abs() < 1e-12);
        assert!((c1.total_usd() - c2.total_usd()).abs() < 1e-12);
    }
}
