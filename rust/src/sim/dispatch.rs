//! Batch lifecycle: arrival → queueing → routing → artifact loading →
//! prefill → decode → finalisation. The mechanism half of the dispatch
//! path; every policy decision (fire-now, desired size, cold-start
//! pricing, memory-pressure resolution) is delegated to the
//! `coordinator::policy` traits in the engine's [`PolicyBundle`].

use std::collections::BTreeMap;

use crate::artifact::{params, ArtifactKind, FunctionSpec, LinkCaps, LinkKind, PhaseCost, Term, Tier};
use crate::cluster::{ContainerId, GpuId};
use crate::coldstart::ColdPath;
use crate::coordinator::policy::{LoadQuery, PolicyEnv};
use crate::coordinator::{Queued, Readiness, Router};
use crate::metrics::{Phase, RequestOutcome};
use crate::sim::engine::{Engine, QueueWakeups};
use crate::sim::events::{EventKind, EventToken};
use crate::sim::flow::Retime;
use crate::trace::Request;

#[derive(Debug, Clone, Copy, PartialEq)]
pub(super) enum BatchState {
    Loading,
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
pub(super) struct Batch {
    pub(super) function: usize,
    pub(super) gpu: GpuId,
    pub(super) requests: Vec<Request>,
    pub(super) load_phases: BTreeMap<Phase, f64>,
    pub(super) t_dispatch: f64,
    pub(super) t_exec_start: f64,
    pub(super) prefill_wall: f64,
    pub(super) state: BatchState,
    /// Reserved KV GB (kept for observability / debug assertions).
    #[allow(dead_code)]
    pub(super) kv_gb: f64,
    pub(super) attached_backbone: bool,
    /// Where the backbone checkpoint was sourced (tiered store only).
    pub(super) backbone_tier: Option<Tier>,
    /// Fault injection: this batch's cold load was drawn as a transient
    /// failure at dispatch; it surfaces when the load completes (the
    /// time was spent either way). Always false with faults off.
    pub(super) failed_load: bool,
    /// The flat-path `LoadDone` event (segmented loads track theirs in
    /// the [`LoadRun`]). Held so a GPU crash can cancel it in O(1);
    /// cleared when the event fires.
    pub(super) load_token: Option<EventToken>,
    /// Which cold-start path this batch's bring-up took (`Warm` when
    /// nothing had to load). Stamped at dispatch, surfaced on every
    /// request outcome (`RequestOutcome::cold_path`).
    pub(super) cold_path: ColdPath,
}

/// One segment of a tiered load: a contended transfer (`link: Some`) or a
/// run of fixed (CPU/driver-side) work merged into one event.
#[derive(Debug, Clone)]
pub(super) struct LoadSeg {
    pub(super) phase: Phase,
    /// `Some(link)` → this segment is a flow on `(node, link)`.
    pub(super) link: Option<LinkKind>,
    /// Solo (uncontended) duration at the configured bandwidths.
    pub(super) dur_s: f64,
    /// Absolute completion time if no link ever contends — the two-level
    /// prefix fold of [`build_load_segs`]; honored verbatim while the run
    /// stays `on_nominal`, which is what makes solo tiered loads
    /// bit-identical to the flat fast path.
    pub(super) nominal_end_s: f64,
}

/// The in-flight state of one segmented (tiered) load.  Flat-path loads
/// (tiers off, or no transfer segments) never create one.
#[derive(Debug, Clone)]
pub(super) struct LoadRun {
    pub(super) node: usize,
    pub(super) segs: Vec<LoadSeg>,
    pub(super) cursor: usize,
    /// True while every completed segment ended exactly on its nominal
    /// schedule; the first contended segment clears it, after which
    /// segments are timed `start + dur` and stretch deltas are folded
    /// into the batch's phase map.
    pub(super) on_nominal: bool,
    pub(super) seg_start_s: f64,
    /// The completion time currently in the event queue (`token`).
    pub(super) cur_end_s: f64,
    pub(super) token: Option<EventToken>,
}

/// Cut a phase plan into [`LoadSeg`]s.  Exactness contract: nominal ends
/// are absolute times computed as `now + (prefix + acc)` where `prefix`
/// folds the phase totals in `Phase` order (the identical op sequence to
/// `load_phases.values().sum()`) and `acc` left-folds the phase's term
/// seconds from 0.0 (the identical sequence to `PhaseCost::total`) — so
/// the last segment's nominal end is bit-equal to `now + total_load`.
/// Contiguous fixed terms within a phase merge into one segment;
/// zero-byte transfers are treated as fixed work (no flow).
pub(super) fn build_load_segs(
    plan: &BTreeMap<Phase, PhaseCost>,
    caps: &LinkCaps,
    now: f64,
) -> Vec<LoadSeg> {
    let mut segs: Vec<LoadSeg> = Vec::new();
    let mut prefix = 0.0f64;
    for (&phase, cost) in plan {
        let mut acc = 0.0f64;
        let mut open_fixed: Option<usize> = None;
        for t in &cost.0 {
            let s = t.seconds(caps);
            acc += s;
            let flow_link = match t {
                Term::Xfer { link, gb } if *gb > 0.0 => Some(*link),
                _ => None,
            };
            let end = now + (prefix + acc);
            match flow_link {
                Some(link) => {
                    segs.push(LoadSeg { phase, link: Some(link), dur_s: s, nominal_end_s: end });
                    open_fixed = None;
                }
                None => match open_fixed {
                    Some(i) => {
                        segs[i].dur_s += s;
                        segs[i].nominal_end_s = end;
                    }
                    None => {
                        open_fixed = Some(segs.len());
                        segs.push(LoadSeg { phase, link: None, dur_s: s, nominal_end_s: end });
                    }
                },
            }
        }
        prefix += acc;
    }
    segs
}

impl Engine {
    // ---------------------------------------------------------- arrivals

    pub(super) fn on_arrival(&mut self, i: usize) {
        // Stream the next arrival in first so it wins same-instant ties
        // against anything this handler schedules.
        self.schedule_next_arrival();
        self.arrived += 1;
        let req = self.requests[i].clone();
        let f = req.function;
        self.queues[f].push(Queued { request: req.id, arrival_s: req.arrival_s });
        self.active.insert(f);
        let armed_at_arrival = self.queue_wakeups[f];
        self.try_dispatch_all(Some(f));
        // Forecast hooks fire AFTER this arrival's dispatch attempt: a
        // predictive agent stages in the background, so its work becomes
        // visible to *later* arrivals — the triggering request must not
        // skip load phases via a physically instantaneous preload.
        {
            let mut env = PolicyEnv {
                cluster: &mut self.cluster,
                registry: &mut self.registry,
                functions: &self.functions,
                rates: &self.rates,
                sharing: self.cfg.backbone_sharing,
                dedicated: &mut self.dedicated,
                stats: &mut self.stats,
            };
            self.preload.on_arrival(f, req.arrival_s, &mut env);
        }
        // A dispatch above already re-armed wakeups for the residual
        // queue (cancelling the pre-dispatch checks); arm only if it
        // didn't.
        if self.queue_wakeups[f] == armed_at_arrival {
            self.arm_queue_wakeups(f);
        }
    }

    /// Wakeups for function `f`'s queue: the debounce settle-point and
    /// the Eq. 3 expiry. Every queue mutation (arrival push, dispatch
    /// take) re-arms, **cancelling** the superseded checks in O(1) —
    /// at most two checks per function are ever live, and a check that
    /// fires is always current.
    pub(super) fn arm_queue_wakeups(&mut self, f: usize) {
        let old = std::mem::take(&mut self.queue_wakeups[f]);
        for tok in old.tokens() {
            self.events.cancel(tok); // inert if the check already fired
        }
        if self.queues[f].is_empty() {
            return;
        }
        let settle = self.events.push(
            self.now + crate::coordinator::batching::DEBOUNCE_S + 1e-3,
            EventKind::QueueCheck(f),
        );
        let mut expiry = None;
        if let Some(t) = self.batching.expiry_time(&self.queues[f]) {
            if t.is_finite() && t > self.now {
                expiry = Some(self.events.push(t, EventKind::QueueCheck(f)));
            }
        }
        self.queue_wakeups[f] = QueueWakeups { settle: Some(settle), expiry };
    }

    pub(super) fn should_dispatch(&self, f: usize) -> bool {
        let target_idle = || self.target_gpu_idle(f);
        self.batching
            .should_dispatch(&self.queues[f], self.now, &target_idle)
    }

    /// Is the GPU this function would route to free to take a prefill now?
    /// Decode-phase jobs do not defer dispatch (decode is memory-bound and
    /// overlaps an incoming prefill well — the reason iteration-level
    /// batching works); loading batches and prefill-phase batches do.
    pub(super) fn target_gpu_idle(&self, f: usize) -> bool {
        let gpu = match self.dedicated.get(&f) {
            Some(&g) => Some(g),
            None => Router::route(&self.cluster, &self.registry, self.spec(f), 1)
                .map(|r| r.gpu),
        };
        let Some(g) = gpu else { return false };
        self.gpu_busy[self.gpu_map.dense(g)] == 0
    }

    /// Global dispatch loop: repeatedly pick the dispatchable queue with
    /// the tightest Eq. 5 deadline margin and dispatch it.
    ///
    /// With a `hint`, only that function is considered — an arrival can
    /// only change its own queue's dispatchability (GPU state is
    /// untouched), so scanning all queues on every arrival would be
    /// wasted work. Completion/offload events pass `None`, which walks
    /// the `active` index (functions with queued work) in ascending
    /// order — identical to the old full scan, since `should_dispatch`
    /// is false for every empty queue.
    pub(super) fn try_dispatch_all(&mut self, hint: Option<usize>) {
        if let Some(f) = hint {
            while self.should_dispatch(f) && !self.blocked.contains_key(&f) {
                if let Err(on) = self.dispatch(f) {
                    // A failed dispatch may itself mutate GPU state
                    // (partial offload): only mark blocked if the queue
                    // still wants to fire.
                    if self.should_dispatch(f) {
                        self.block(f, on);
                    }
                    return;
                }
            }
            return;
        }
        loop {
            let mut ready: Vec<usize> = self
                .active
                .iter()
                .copied()
                .filter(|&f| self.should_dispatch(f) && !self.blocked.contains_key(&f))
                .collect();
            if ready.is_empty() {
                return;
            }
            // Eq. 5 prioritisation (adaptive policies; fixed mode FIFO).
            if self.batching.prioritise_by_margin() {
                ready.sort_by(|&a, &b| self.margin(a).total_cmp(&self.margin(b)));
            }
            let f = ready[0];
            if let Err(on) = self.dispatch(f) {
                self.block(f, on);
            }
        }
    }

    fn block(&mut self, f: usize, on: Option<GpuId>) {
        self.blocked.insert(f, on);
        self.stats.blocked_dispatches += 1;
    }

    pub(super) fn margin(&self, f: usize) -> f64 {
        let gpu_hint = self
            .dedicated
            .get(&f)
            .copied()
            .or_else(|| self.registry.hosts(self.spec(f).model.name).first().copied());
        let m = gpu_hint
            .map(|g| self.execs[self.gpu_map.dense(g)].contention() + 1)
            .unwrap_or(1);
        self.queues[f].deadline_margin(self.now, m)
    }

    // ---------------------------------------------------------- dispatch

    /// Dispatch one batch for function `f`. `Err` means blocked — on the
    /// returned GPU's memory (a blocking offload policy waits; dynamic
    /// offloading avoids this), or `Err(None)` when routing found no
    /// GPU at all. The blocked map records the target so a retry fires
    /// when *that* GPU frees memory.
    pub(super) fn dispatch(&mut self, f: usize) -> Result<(), Option<GpuId>> {
        let spec = self.spec(f).clone();
        let gpu = match self.dedicated.get(&f) {
            // A dedicated (serverful) route is pinned: if its GPU is
            // down (fault injection) the function blocks until repair.
            Some(&g) if !self.cluster.gpu_is_up(g) => return Err(Some(g)),
            Some(&g) => g,
            None => match Router::route(&self.cluster, &self.registry, &spec, 1) {
                Some(r) => self.maybe_replicate(&spec, r.gpu),
                None => return Err(None),
            },
        };

        // Desired batch under the policy's sizing rule (Eq. 2 SLO bound
        // for adaptive, the fixed size otherwise).
        let want = self.batching.desired_batch(&self.queues[f]);

        // Memory needed: KV for the batch + any artifacts still missing.
        let readiness = Router::readiness(&self.cluster, &spec, gpu);
        let mut need_gb = spec.model.kv_per_request_gb * want as f64;
        if !readiness.backbone_on_gpu {
            need_gb += spec.model.weights_gb;
        }
        if !readiness.adapter_on_gpu {
            need_gb += spec.model.adapter_gb;
        }
        if !readiness.kernel_on_gpu {
            need_gb += spec.model.kernel_gb;
        }
        if !readiness.cuda_context {
            need_gb += params::CUDA_CONTEXT_GB;
        }

        if self.cluster.gpu(gpu).free_gb() < need_gb {
            let spill = self.cluster_spill_target(gpu);
            let plan = self.offload.try_free(
                &mut self.cluster,
                &mut self.registry,
                gpu,
                need_gb,
                &[f],
                &self.functions,
                &self.rates,
                spill,
            );
            match plan {
                Some(plan) => {
                    self.stats.offload_events += 1;
                    self.stats.offloaded_gb += plan.freed_gb;
                    if self.cluster.gpu(gpu).free_gb() < need_gb {
                        // Even full eviction can't fit: shrink the batch.
                        let kv_free = self.cluster.gpu(gpu).free_gb()
                            - (need_gb - spec.model.kv_per_request_gb * want as f64);
                        let fit = (kv_free / spec.model.kv_per_request_gb).floor() as i64;
                        if fit < 1 {
                            return Err(Some(gpu));
                        }
                    }
                }
                None => {
                    // Blocking policy: wait until completions free memory.
                    let kv_free = self.cluster.gpu(gpu).free_gb()
                        - (need_gb - spec.model.kv_per_request_gb * want as f64);
                    if (kv_free / spec.model.kv_per_request_gb).floor() < 1.0 {
                        return Err(Some(gpu));
                    }
                }
            }
        }

        // Final batch size bounded by what actually fits.
        let fixed_gb = need_gb - spec.model.kv_per_request_gb * want as f64;
        let kv_budget = self.cluster.gpu(gpu).free_gb() - fixed_gb;
        let cap = (kv_budget / spec.model.kv_per_request_gb).floor().max(0.0) as usize;
        if cap == 0 {
            return Err(Some(gpu));
        }
        let taken = self.queues[f].take_batch(cap.min(want));
        debug_assert!(!taken.is_empty());
        if self.queues[f].is_empty() {
            self.active.remove(&f);
        }
        let reqs: Vec<Request> = taken
            .iter()
            .map(|q| self.requests[self.request_index[&q.request]].clone())
            .collect();
        let b = reqs.len();

        // Mutate ledgers: make everything resident, reserve KV.
        let batch_id = self.next_batch;
        self.next_batch += 1;
        let (mut plan, backbone_tier, restored) = self.make_resident(f, &spec, gpu, readiness);
        // Cold-start subsystem: a pipelined-strategy function splits a
        // below-RAM backbone fetch across idle sibling nodes — the
        // target's slice shrinks to 1/K here; the K-1 sibling shards
        // start after the batch exists (`start_pipe_shards`).
        let pipe = if self.cfg.cold_start.is_some() && self.cfg.tiers.is_some() {
            self.plan_pipelined(f, gpu, &mut plan)
        } else {
            None
        };
        let kv_gb = spec.model.kv_per_request_gb * b as f64;
        self.cluster
            .gpu_mut(gpu)
            .reserve_kv(batch_id, kv_gb)
            .expect("kv sized to fit");
        let attached = if self.cfg.backbone_sharing {
            self.registry
                .attach(&mut self.cluster, spec.model.name, gpu, f)
                .is_ok()
        } else {
            false
        };

        // §4.2: batching "avoid[s] creating new instances". A dispatch
        // while this function already has in-flight batches forces the
        // platform to scale out a NEW process instance: it pays its own
        // CUDA context plus per-context kernel handles (contexts are
        // per-process; pre-loaded artifacts shortcut the JIT but not the
        // context). This is what makes no-batching (NAB#1) slow under
        // concurrency even when everything is pre-loaded.
        let concurrent = self.fn_inflight[f] > 0;
        if concurrent && !self.cfg.serverful {
            plan.entry(Phase::ContainerInit)
                .or_default()
                .push(Term::Fixed(params::CUDA_CONTEXT_INIT_S));
            plan.entry(Phase::KernelCompile)
                .or_default()
                .push(Term::Fixed(self.preload.scaleout_kernel_s(f, &spec.model)));
        }

        // Fold the term plan into the historical phase → seconds map.
        // Appended terms extend each phase's left fold, so every value —
        // and `total_load` below — is bit-identical to the old flat
        // accumulation (see `artifact::PhaseCost`).
        let caps = self.cfg.tiers.map(|t| t.caps()).unwrap_or(LinkCaps::DEFAULT);
        let load_phases: BTreeMap<Phase, f64> =
            plan.iter().map(|(&p, c)| (p, c.total(&caps))).collect();
        let total_load: f64 = load_phases.values().sum();
        if total_load > 0.0 {
            self.stats.cold_dispatches += 1;
        } else {
            self.stats.warm_dispatches += 1;
        }
        let mut cold_path = if total_load > 0.0 { ColdPath::Tiered } else { ColdPath::Warm };
        if restored {
            cold_path = ColdPath::SnapshotRestore;
        }
        if pipe.is_some() {
            cold_path = ColdPath::Pipelined;
        }
        // Fault injection: a cold load may fail in transit. The draw
        // happens only when an injector exists AND there is a load to
        // fail, so the faultless path performs zero RNG draws (the
        // `faults: None` bit-identity contract).
        let failed_load = match self.injector.as_mut() {
            Some(inj) if total_load > 0.0 => inj.load_fails(),
            _ => false,
        };
        self.batches.insert(
            batch_id,
            Batch {
                function: f,
                gpu,
                requests: reqs,
                load_phases,
                t_dispatch: self.now,
                t_exec_start: 0.0,
                prefill_wall: 0.0,
                state: BatchState::Loading,
                kv_gb,
                attached_backbone: attached,
                backbone_tier,
                failed_load,
                load_token: None,
                cold_path,
            },
        );
        self.fn_inflight[f] += 1;
        let d = self.gpu_map.dense(gpu);
        self.gpu_busy[d] += 1;
        // The batch starts in `Loading`: the GPU bills as active from
        // this instant (instance allocated and working).
        self.gpu_loading[d] += 1;
        self.reclassify_gpu(gpu);
        // Tiered store: a load with transfer segments runs as a sequence
        // of flows under fair-share contention. Loads with no transfers
        // (and every flat-path load) keep the single pre-timed event —
        // the literal historical code path.
        let mut segmented = false;
        if self.cfg.tiers.is_some() {
            let segs = build_load_segs(&plan, &caps, self.now);
            if segs.iter().any(|s| s.link.is_some()) {
                self.load_runs.insert(
                    batch_id,
                    LoadRun {
                        node: gpu.node,
                        segs,
                        cursor: 0,
                        on_nominal: true,
                        seg_start_s: self.now,
                        cur_end_s: 0.0,
                        token: None,
                    },
                );
                self.start_load_segment(batch_id);
                segmented = true;
            }
        }
        if !segmented {
            // A flat load dispatched onto an already-degraded GPU
            // stretches by the active slowdown factor, the extra folded
            // into its last phase so TTFT still equals the phase sum
            // (mirrors `retime_gpu_rate` in sim/fault.rs). Factor 1.0 —
            // the only value a fault-free run can hold — leaves the
            // historical timing bit-identical.
            let factor = self.degrade_factor[d];
            let mut wall = total_load;
            if factor != 1.0 && total_load > 0.0 {
                wall = total_load * factor;
                let batch = self.batches.get_mut(&batch_id).expect("just inserted");
                if let Some((_, v)) = batch.load_phases.iter_mut().next_back() {
                    *v += wall - total_load;
                }
                self.stats.degrade_retimes += 1;
            }
            let tok = self.events.push(self.now + wall, EventKind::LoadDone(batch_id));
            self.batches.get_mut(&batch_id).expect("just inserted").load_token = Some(tok);
        }
        // Cold-start subsystem: launch the K-1 sibling shards after the
        // target's own (scaled) segmented load joined its links, so the
        // join order — and every retime it causes — is deterministic.
        if let Some(pipe) = pipe {
            debug_assert!(segmented, "a pipelined backbone fetch is always segmented");
            self.start_pipe_shards(batch_id, pipe);
        }
        // Residual queue: cancel the pre-dispatch checks and re-arm for
        // what is left.
        self.arm_queue_wakeups(f);
        Ok(())
    }

    /// Locality-vs-contention trade (§3.1 challenge 3): the router prefers
    /// GPUs that already host the backbone, but when every host is
    /// congested and a colder GPU has room for another shared copy, pay
    /// the one-time replica load — all later functions of this model
    /// attach to it for free.
    ///
    /// Walks the cluster's free-memory ordering from the top: the first
    /// idle GPU with room is the max-free idle GPU (equal free resolves
    /// to the higher id, matching the historical full scan). Only under
    /// total saturation does the walk see every GPU.
    pub(super) fn maybe_replicate(&self, spec: &FunctionSpec, routed: GpuId) -> GpuId {
        if !self.cfg.backbone_sharing {
            return routed;
        }
        let contention = self.execs[self.gpu_map.dense(routed)].contention();
        if contention < 2 {
            return routed;
        }
        let need = spec.model.gpu_resident_gb() + spec.model.kv_per_request_gb;
        let execs = &self.execs;
        let map = &self.gpu_map;
        let cluster = &self.cluster;
        self.cluster
            .scan_free_desc(|g, free| {
                cluster.gpu_is_up(g)
                    && free >= need
                    && execs[map.dense(g)].contention() == 0
            })
            .unwrap_or(routed)
    }

    pub(super) fn cluster_spill_target(&self, gpu: GpuId) -> Option<ContainerId> {
        self.cluster
            .nodes
            .get(gpu.node)
            .and_then(|n| n.containers.first())
            .map(|c| c.id)
    }

    /// Make all artifacts of `f` resident on `gpu`, returning the phase →
    /// cost-term plan for whatever had to be loaded (§6.3 breakdown), the
    /// memory tier the cold backbone was sourced from (None when warm
    /// or when the tiered store is disabled), and whether a resident
    /// snapshot short-circuited the bring-up (`sim::coldstart`). The
    /// preload policy prices the phases; the ledger mutations below are
    /// mechanism, identical for every policy.
    pub(super) fn make_resident(
        &mut self,
        f: usize,
        spec: &FunctionSpec,
        gpu: GpuId,
        ready: Readiness,
    ) -> (BTreeMap<Phase, PhaseCost>, Option<Tier>, bool) {
        let m = &spec.model;
        // A pre-warmed instance (policy-staged kernels + CUDA context) is
        // as good as a keep-alive-warm one — the §6.3 claim that fully
        // pre-loaded cold starts run at warm-start speed.
        let warm_instance = self.preload.prewarmed(ready)
            || (self.keepalive.is_warm(f, self.now) && ready.cuda_context);
        // O(log) container-residency lookups via the cluster index — the
        // old closures scanned every container per cold dispatch.
        let container_has = |kind: ArtifactKind| self.cluster.container_has(f, kind);
        // Backbone staging copies are per-model, not per-function: any
        // function of the same model can read the host-RAM copy (the
        // peer list is indexed once at construction, not re-scanned).
        let container_has_model_backbone = {
            let peers: &[usize] =
                self.model_peers.get(m.name).map(Vec::as_slice).unwrap_or_default();
            peers
                .iter()
                .any(|&fid| self.cluster.container_has(fid, ArtifactKind::Backbone))
        };
        let query = LoadQuery {
            function: f,
            model: m,
            ready,
            warm_instance,
            container_has_library: container_has(ArtifactKind::Library),
            container_has_adapter: container_has(ArtifactKind::Adapter),
            container_has_own_backbone: container_has(ArtifactKind::Backbone),
            container_has_model_backbone,
        };
        let mut plan = self.preload.load_plan(&query);
        // Cross-zone artifact fetch (sharded runs only): when a peer zone
        // hosts this model but no local GPU does, the cold backbone comes
        // over the datacenter network from the peer's GPU memory
        // (λScale-style GPU-to-GPU multicast) instead of the checkpoint
        // store — cheaper by `CROSS_ZONE_BACKBONE_FACTOR`. `peer_models`
        // is empty outside sharded runs, so zones=1 takes the
        // short-circuit and performs zero additional float operations.
        // Runs BEFORE tier resolution: the factor applies to the remote
        // fetch the flat model priced, and scaling the terms folds to the
        // same bits as scaling the folded sum (the factor is a power of
        // two, see `PhaseCost::scale`).
        if !ready.backbone_on_gpu && !self.peer_models.is_empty() {
            if let Some(cost) = plan.get_mut(&Phase::BackboneLoad) {
                if cost.total_default() > 0.0
                    && self.peer_models.contains(m.name)
                    && self.registry.hosts(m.name).is_empty()
                {
                    cost.scale(params::CROSS_ZONE_BACKBONE_FACTOR);
                    self.stats.cross_zone_fetches += 1;
                }
            }
        }
        // Cold-start subsystem: a snapshot-restore-strategy function
        // whose snapshot sits in the node's host cache skips the whole
        // segmented bring-up for a near-constant restore (the plan is
        // replaced wholesale; see `sim::coldstart`). Fully gated on the
        // `cold_start` knob, so `None` runs never reach the helper.
        let mut restored = false;
        if self.cfg.cold_start.is_some() && self.cfg.tiers.is_some() {
            restored = self.try_snapshot_restore(f, gpu, &mut plan);
        }
        // Tiered store: resolve where the cold backbone actually comes
        // from by walking the memory hierarchy — host-RAM checkpoint
        // cache, then node NVMe (when seeded), then the remote store —
        // and retarget the transfer terms accordingly. The cache policy
        // (fifth trait in the bundle) decides admission and eviction.
        let mut backbone_tier = None;
        if restored {
            // The restore replaced the plan; the hierarchy walk must not
            // re-source it (a restore is not a tiered cold load in the
            // tier-hit ledger — it never touched the checkpoint store).
            backbone_tier = Some(Tier::ContainerRam);
        } else if let Some(tiers) = self.cfg.tiers {
            if let Some(cost) = plan.get_mut(&Phase::BackboneLoad) {
                if cost.has_xfer() {
                    self.stats.tiered_cold_loads += 1;
                    let cache = &mut self.cluster.nodes[gpu.node].cache;
                    if !cost.fetches_below_ram() {
                        // Already sourced from host RAM (e.g. a peer
                        // container's staged copy): PCIe-only transfer.
                        self.stats.tier_hits_ram += 1;
                        backbone_tier = Some(Tier::ContainerRam);
                    } else if cache.enabled() && cache.contains(m.name) {
                        self.cache.on_hit(cache, m.name, self.now);
                        cost.source_from_ram();
                        self.stats.tier_hits_ram += 1;
                        backbone_tier = Some(Tier::ContainerRam);
                    } else {
                        if tiers.ssd_seeded {
                            // Checkpoint pre-seeded on node NVMe: the
                            // flat model already priced an NVMe read, so
                            // keep the terms (bit-identical fold).
                            self.stats.tier_hits_ssd += 1;
                            backbone_tier = Some(Tier::Ssd);
                        } else {
                            cost.source_from_remote();
                            self.stats.tier_hits_remote += 1;
                            backbone_tier = Some(Tier::Remote);
                        }
                        if cache.enabled() {
                            let evicted =
                                self.cache.admit(cache, m.name, m.weights_gb, self.now);
                            self.stats.cache_evictions += evicted;
                        }
                    }
                }
            }
        }

        // Ledger mutations, driven by readiness alone.
        if !ready.backbone_on_gpu {
            if self.cfg.backbone_sharing {
                self.registry
                    .load(&mut self.cluster, m.name, m.weights_gb, gpu)
                    .expect("sized in dispatch");
            } else {
                self.cluster
                    .gpu_mut(gpu)
                    .place_artifact(f, ArtifactKind::Backbone, m.weights_gb)
                    .expect("sized in dispatch");
            }
        }
        if !ready.adapter_on_gpu {
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::Adapter, m.adapter_gb)
                .expect("sized in dispatch");
        }
        if !ready.kernel_on_gpu {
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::CudaKernel, m.kernel_gb)
                .expect("sized in dispatch");
        }
        if !ready.cuda_context {
            self.cluster
                .gpu_mut(gpu)
                .create_cuda_context(f)
                .expect("sized in dispatch");
        }
        // Checkpoint admissions above may have evicted snapshots; keep
        // the storage-surcharge integrand current (no-op with the
        // cold-start knob off).
        self.refresh_snap_gb();
        (plan, backbone_tier, restored)
    }

    // ------------------------------------------------- tiered load path

    /// Start the current segment of `batch_id`'s load run: join its flow
    /// onto the node link (transfer segments) or arm a plain timer
    /// (fixed segments), then apply any retimes the join caused.
    ///
    /// While the run is `on_nominal`, the segment's pre-folded
    /// `nominal_end_s` is passed through verbatim — `FlowNet` schedules a
    /// solo flow at exactly that instant, never through arithmetic, so an
    /// uncontended tiered load fires its events at bit-identical times to
    /// the flat path.
    pub(super) fn start_load_segment(&mut self, batch_id: u64) {
        let (node, seg, on_nominal) = {
            let run = self.load_runs.get_mut(&batch_id).expect("load run exists");
            run.seg_start_s = self.now;
            (run.node, run.segs[run.cursor].clone(), run.on_nominal)
        };
        let nominal =
            if on_nominal { seg.nominal_end_s } else { self.now + seg.dur_s };
        let (end, retimes) = match seg.link {
            Some(link) => {
                self.flows.join(node, link, batch_id, seg.dur_s, nominal, self.now)
            }
            None => (nominal, Vec::new()),
        };
        let token = self.events.push(end, EventKind::LoadDone(batch_id));
        let run = self.load_runs.get_mut(&batch_id).expect("load run exists");
        run.cur_end_s = end;
        run.token = Some(token);
        self.apply_load_retimes(retimes);
    }

    /// Re-arm the completion events of flows whose fair share changed:
    /// O(1) cancel of the stale token, push at the new end. The touched
    /// runs lose nominal status — their clocks now belong to `FlowNet`.
    /// Pipelined shard/consolidation flows carry synthetic ids disjoint
    /// from batch ids and re-arm their own event kinds instead.
    pub(super) fn apply_load_retimes(&mut self, retimes: Vec<Retime>) {
        for r in retimes {
            if crate::sim::coldstart::is_pipe_id(r.batch) {
                self.retime_pipe_flow(r.batch, r.end_s);
                continue;
            }
            let run = self.load_runs.get_mut(&r.batch).expect("retimed run exists");
            if let Some(tok) = run.token.take() {
                self.events.cancel(tok);
            }
            run.on_nominal = false;
            run.cur_end_s = r.end_s;
            run.token = Some(self.events.push(r.end_s, EventKind::LoadDone(r.batch)));
            self.stats.load_retimes += 1;
        }
    }

    /// A `LoadDone` event fired for `batch_id`. Flat-path loads (no
    /// [`LoadRun`]) complete outright; segmented loads retire the current
    /// segment, fold any contention stretch into the batch's phase map
    /// (so TTFT stays the sum of its phases), and either start the next
    /// segment or complete.
    pub(super) fn on_load_event(&mut self, batch_id: u64) {
        if !self.load_runs.contains_key(&batch_id) {
            return self.on_load_done(batch_id);
        }
        let (node, seg, seg_start) = {
            let run = &self.load_runs[&batch_id];
            (run.node, run.segs[run.cursor].clone(), run.seg_start_s)
        };
        if let Some(link) = seg.link {
            let (was_nominal, retimes) =
                self.flows.finish(node, link, batch_id, self.now);
            self.apply_load_retimes(retimes);
            if !was_nominal {
                let run = self.load_runs.get_mut(&batch_id).expect("run exists");
                run.on_nominal = false;
                // Contention stretch, attributed to this segment's phase.
                // Guarded so an exactly-on-time finish adds no term (and
                // a nominal finish never reaches here at all): the phase
                // breakdown stays bit-identical whenever latency is.
                let delta = (self.now - seg_start) - seg.dur_s;
                if delta != 0.0 {
                    let batch = self.batches.get_mut(&batch_id).expect("batch");
                    *batch.load_phases.entry(seg.phase).or_insert(0.0) += delta;
                }
            }
        }
        let run = self.load_runs.get_mut(&batch_id).expect("run exists");
        run.cursor += 1;
        if run.cursor == run.segs.len() {
            self.load_runs.remove(&batch_id);
            self.on_load_done(batch_id);
        } else {
            self.start_load_segment(batch_id);
        }
    }

    // ------------------------------------------------------- exec events

    pub(super) fn on_load_done(&mut self, batch_id: u64) {
        // Fault injection: the load was drawn as a transient failure at
        // dispatch time — the batch dies here instead of starting
        // prefill (its requests retry with backoff; see `sim::fault`).
        // Any sibling shards die with it (they DMAed for nothing).
        if self.batches[&batch_id].failed_load {
            self.abort_pipe_run(batch_id);
            return self.on_load_failed(batch_id);
        }
        // Pipelined cold start: the target's own 1/K slice is done, but
        // prefill needs the whole checkpoint — hold in `Loading` until
        // the last sibling shard lands (`sim::coldstart::on_shard_done`
        // folds the wait into the phase map and completes the load).
        if self.pipe_hold_for_shards(batch_id) {
            return;
        }
        self.complete_load(batch_id);
    }

    /// Loading → Prefill: every byte of the batch's bring-up has landed.
    /// The tail of `on_load_done`, split out so a pipelined load can
    /// complete from its last shard event instead of its own `LoadDone`.
    pub(super) fn complete_load(&mut self, batch_id: u64) {
        let (gpu, f, b, cold_path) = {
            let batch = self.batches.get_mut(&batch_id).expect("batch exists");
            batch.state = BatchState::Prefill;
            batch.t_exec_start = self.now;
            (batch.gpu, batch.function, batch.requests.len(), batch.cold_path)
        };
        // Loading → Prefill: the loading count drops as the exec job
        // starts; the schedule_tick below reclassifies over both.
        let d = self.gpu_map.dense(gpu);
        self.gpu_loading[d] -= 1;
        let work = self.spec(f).model.prefill_s(b);
        self.execs[d].add(self.now, batch_id, work);
        self.schedule_tick(gpu);
        // Cold-start subsystem: a completed bring-up may seed a snapshot
        // build (snapshot-restore strategy) and clears any crash-forced
        // tiered fallback. Gated so `cold_start: None` skips the call.
        if self.cfg.cold_start.is_some() {
            self.on_cold_load_completed(f, gpu.node, cold_path);
        }
    }

    /// (Re)schedule the single completion tick for `gpu`: the superseded
    /// tick (scheduled against the pre-mutation job set) is cancelled
    /// outright, so exactly one live `GpuTick` exists per busy GPU and a
    /// tick that fires is always current. Every exec mutation funnels
    /// through here, so this is also where the billing aggregates learn
    /// about exec start/finish.
    pub(super) fn schedule_tick(&mut self, gpu: GpuId) {
        self.reclassify_gpu(gpu);
        let d = self.gpu_map.dense(gpu);
        if let Some(tok) = self.tick_tokens[d].take() {
            self.events.cancel(tok);
        }
        if let Some((_, t)) = self.execs[d].next_completion() {
            let tok = self.events.push(t.max(self.now), EventKind::GpuTick(gpu));
            self.tick_tokens[d] = Some(tok);
        }
    }

    pub(super) fn on_gpu_tick(&mut self, gpu: GpuId) {
        // The job this tick was scheduled for (ticks are cancelled on
        // every job-set mutation, so a firing tick is never stale).
        let exec = &mut self.execs[self.gpu_map.dense(gpu)];
        let next = exec.next_completion();
        let mut finished = exec.finished_at(self.now);
        if finished.is_empty() {
            // Float-drift guard: the scheduled job can carry residual
            // work marginally above the sweep epsilon at its own
            // completion instant; without this it would re-schedule a
            // same-time tick forever. The job was due now — it finishes.
            if let Some((job, t)) = next {
                if t <= self.now + 1e-9 && exec.force_complete(self.now, job) {
                    finished.push(job);
                }
            }
        }
        for id in finished {
            self.on_job_done(id);
        }
        self.schedule_tick(gpu);
    }

    pub(super) fn on_job_done(&mut self, batch_id: u64) {
        let state = self.batches[&batch_id].state;
        match state {
            BatchState::Prefill => {
                let (gpu, f, b, max_out) = {
                    let batch = self.batches.get_mut(&batch_id).unwrap();
                    batch.prefill_wall = self.now - batch.t_exec_start;
                    batch.state = BatchState::Decode;
                    (
                        batch.gpu,
                        batch.function,
                        batch.requests.len(),
                        batch.requests.iter().map(|r| r.output_tokens).max().unwrap(),
                    )
                };
                // Prefill slot freed on this GPU (decode overlaps).
                let d = self.gpu_map.dense(gpu);
                self.gpu_busy[d] -= 1;
                let work = self.spec(f).model.tpot_at(b) * max_out as f64;
                let exec = &mut self.execs[d];
                exec.add_weighted(
                    self.now,
                    batch_id,
                    work,
                    crate::sim::exec::DECODE_WEIGHT,
                );
                self.schedule_tick(gpu);
                // Prefill slot freed: queues waiting on this GPU may go.
                self.try_dispatch_all(None);
            }
            BatchState::Decode => self.finalize_batch(batch_id),
            BatchState::Loading => unreachable!("loading batches are not exec jobs"),
        }
    }

    pub(super) fn finalize_batch(&mut self, batch_id: u64) {
        // Pipelined cold start: the instance cannot release until its
        // consolidation transfer (gathering the sibling slices) lands —
        // decode may outrun it; the `ConsolidateDone` event re-enters.
        if self.pipe_defer_finalize(batch_id) {
            return;
        }
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let f = batch.function;
        self.fn_inflight[f] -= 1;
        let b = batch.requests.len();
        let decode_start = batch.t_exec_start + batch.prefill_wall;
        let decode_wall = self.now - decode_start;
        let max_out = batch
            .requests
            .iter()
            .map(|r| r.output_tokens)
            .max()
            .unwrap()
            .max(1) as f64;

        for r in &batch.requests {
            let mut phases = batch.load_phases.clone();
            let queue_wait = batch.t_dispatch - r.arrival_s;
            *phases.entry(Phase::Queue).or_insert(0.0) += queue_wait.max(0.0);
            phases.insert(Phase::Prefill, batch.prefill_wall);
            // Requests stop decoding at their own length; wall time scales
            // proportionally under processor sharing.
            let own_decode = decode_wall * r.output_tokens as f64 / max_out;
            phases.insert(Phase::Decode, own_decode);
            let tpot = own_decode / r.output_tokens.max(1) as f64;
            let mut outcome: RequestOutcome =
                crate::metrics::outcome_from_phases(r, phases, tpot, b);
            outcome.backbone_tier = batch.backbone_tier;
            outcome.cold_path = batch.cold_path;
            if self.injector.is_some() {
                self.retry_count.remove(&r.id);
            }
            self.emit_request_complete(outcome);
        }

        // Release resources.
        self.cluster.gpu_mut(batch.gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self
                .registry
                .detach(&mut self.cluster, &crate::sharing::IpcHandle {
                    model,
                    gpu: batch.gpu,
                    function: f,
                });
        }
        // Keep-alive (serverless): (re)arm the single expiry sweep and
        // bump the billing warm counts on the GPUs hosting `f` (no-op
        // when the window merely extends).
        if !self.cfg.serverful {
            self.keepalive.touch(f, self.now);
            self.note_function_warm(f);
            self.arm_keepalive();
        }
        // Memory freed on this GPU: retry the blocked functions whose
        // dispatch outcome this can change — not every blocked function
        // cluster-wide.
        let g = batch.gpu;
        let retry: Vec<usize> = self
            .blocked
            .iter()
            .filter(|&(&bf, &on)| self.blocked_retry_applies(bf, on, g))
            .map(|(&bf, _)| bf)
            .collect();
        self.stats.blocked_retries += retry.len();
        for bf in retry {
            self.blocked.remove(&bf);
        }
        self.try_dispatch_all(None);
    }

    /// Could memory freed on `freed` change blocked function `f`'s
    /// dispatch outcome? A dedicated (serverful) function's route is
    /// pinned, so only its own GPU's completions matter — the targeted
    /// half of the fix. A routed function must retry on every finalize
    /// (like the old `blocked.clear()`): the router scores *every*
    /// candidate on free memory and `maybe_replicate` may pick any idle
    /// GPU cluster-wide, so restricting by the blocked-on GPU or the
    /// backbone host set would miss legitimate re-routes.
    fn blocked_retry_applies(&self, f: usize, on: Option<GpuId>, freed: GpuId) -> bool {
        if on.is_none() || on == Some(freed) {
            return true;
        }
        match self.dedicated.get(&f) {
            Some(&d) => d == freed,
            None => true,
        }
    }
}
