//! Batch lifecycle: arrival → queueing → routing → artifact loading →
//! prefill → decode → finalisation. The mechanism half of the dispatch
//! path; every policy decision (fire-now, desired size, cold-start
//! pricing, memory-pressure resolution) is delegated to the
//! `coordinator::policy` traits in the engine's [`PolicyBundle`].

use std::collections::BTreeMap;

use crate::artifact::{params, ArtifactKind, FunctionSpec};
use crate::cluster::{ContainerId, GpuId};
use crate::coordinator::policy::{LoadQuery, PolicyEnv};
use crate::coordinator::{Queued, Readiness, Router};
use crate::metrics::{Phase, RequestOutcome};
use crate::sim::engine::Engine;
use crate::sim::events::EventKind;
use crate::trace::Request;

#[derive(Debug, Clone, Copy, PartialEq)]
pub(super) enum BatchState {
    Loading,
    Prefill,
    Decode,
}

#[derive(Debug, Clone)]
pub(super) struct Batch {
    pub(super) function: usize,
    pub(super) gpu: GpuId,
    pub(super) requests: Vec<Request>,
    pub(super) load_phases: BTreeMap<Phase, f64>,
    pub(super) t_dispatch: f64,
    pub(super) t_exec_start: f64,
    pub(super) prefill_wall: f64,
    pub(super) state: BatchState,
    /// Reserved KV GB (kept for observability / debug assertions).
    #[allow(dead_code)]
    pub(super) kv_gb: f64,
    pub(super) attached_backbone: bool,
}

impl Engine {
    // ---------------------------------------------------------- arrivals

    pub(super) fn on_arrival(&mut self, i: usize) {
        let req = self.requests[i].clone();
        let f = req.function;
        self.queues[f].push(Queued { request: req.id, arrival_s: req.arrival_s });
        self.try_dispatch_all(Some(f));
        // Forecast hooks fire AFTER this arrival's dispatch attempt: a
        // predictive agent stages in the background, so its work becomes
        // visible to *later* arrivals — the triggering request must not
        // skip load phases via a physically instantaneous preload.
        {
            let mut env = PolicyEnv {
                cluster: &mut self.cluster,
                registry: &mut self.registry,
                functions: &self.functions,
                rates: &self.rates,
                sharing: self.cfg.backbone_sharing,
                dedicated: &mut self.dedicated,
                stats: &mut self.stats,
            };
            self.policies.preload.on_arrival(f, req.arrival_s, &mut env);
        }
        // Wakeups: debounce settle-point and the Eq. 3 expiry.
        if !self.queues[f].is_empty() {
            self.events.push(
                self.now + crate::coordinator::batching::DEBOUNCE_S + 1e-3,
                EventKind::QueueCheck(f),
            );
        }
        if let Some(t) = self.policies.batching.expiry_time(&self.queues[f]) {
            if t.is_finite() && t > self.now {
                self.events.push(t, EventKind::QueueCheck(f));
            }
        }
    }

    pub(super) fn should_dispatch(&self, f: usize) -> bool {
        let target_idle = || self.target_gpu_idle(f);
        self.policies
            .batching
            .should_dispatch(&self.queues[f], self.now, &target_idle)
    }

    /// Is the GPU this function would route to free to take a prefill now?
    /// Decode-phase jobs do not defer dispatch (decode is memory-bound and
    /// overlaps an incoming prefill well — the reason iteration-level
    /// batching works); loading batches and prefill-phase batches do.
    pub(super) fn target_gpu_idle(&self, f: usize) -> bool {
        let gpu = match self.dedicated.get(&f) {
            Some(&g) => Some(g),
            None => Router::route(&self.cluster, &self.registry, self.spec(f), 1)
                .map(|r| r.gpu),
        };
        let Some(g) = gpu else { return false };
        !self.batches.values().any(|b| {
            b.gpu == g && matches!(b.state, BatchState::Loading | BatchState::Prefill)
        })
    }

    /// Global dispatch loop: repeatedly pick the dispatchable queue with
    /// the tightest Eq. 5 deadline margin and dispatch it.
    ///
    /// With a `hint`, only that function is considered — an arrival can
    /// only change its own queue's dispatchability (GPU state is
    /// untouched), so scanning all queues on every arrival would be
    /// wasted work. Completion/offload events pass `None` for the full
    /// margin-ordered scan.
    pub(super) fn try_dispatch_all(&mut self, hint: Option<usize>) {
        if let Some(f) = hint {
            while self.should_dispatch(f)
                && !self.blocked.contains(&f)
                && self.dispatch(f)
            {}
            if self.should_dispatch(f) && !self.blocked.contains(&f) {
                self.blocked.push(f);
                self.stats.blocked_dispatches += 1;
            }
            return;
        }
        loop {
            let mut ready: Vec<usize> = (0..self.queues.len())
                .filter(|&f| self.should_dispatch(f) && !self.blocked.contains(&f))
                .collect();
            if ready.is_empty() {
                return;
            }
            // Eq. 5 prioritisation (adaptive policies; fixed mode FIFO).
            if self.policies.batching.prioritise_by_margin() {
                ready.sort_by(|&a, &b| {
                    let ma = self.margin(a);
                    let mb = self.margin(b);
                    ma.partial_cmp(&mb).unwrap()
                });
            }
            let f = ready[0];
            if !self.dispatch(f) {
                self.blocked.push(f);
                self.stats.blocked_dispatches += 1;
            }
        }
    }

    pub(super) fn margin(&self, f: usize) -> f64 {
        let gpu_hint = self
            .dedicated
            .get(&f)
            .copied()
            .or_else(|| self.registry.hosts(self.spec(f).model.name).first().copied());
        let m = gpu_hint
            .map(|g| self.execs[&g].contention() + 1)
            .unwrap_or(1);
        self.queues[f].deadline_margin(self.now, m)
    }

    // ---------------------------------------------------------- dispatch

    /// Dispatch one batch for function `f`. Returns false when blocked on
    /// GPU memory (a blocking offload policy waits; dynamic offloading
    /// avoids this).
    pub(super) fn dispatch(&mut self, f: usize) -> bool {
        let spec = self.spec(f).clone();
        let gpu = match self.dedicated.get(&f) {
            Some(&g) => g,
            None => match Router::route(&self.cluster, &self.registry, &spec, 1) {
                Some(r) => self.maybe_replicate(&spec, r.gpu),
                None => return false,
            },
        };

        // Desired batch under the policy's sizing rule (Eq. 2 SLO bound
        // for adaptive, the fixed size otherwise).
        let want = self.policies.batching.desired_batch(&self.queues[f]);

        // Memory needed: KV for the batch + any artifacts still missing.
        let readiness = Router::readiness(&self.cluster, &spec, gpu);
        let mut need_gb = spec.model.kv_per_request_gb * want as f64;
        if !readiness.backbone_on_gpu {
            need_gb += spec.model.weights_gb;
        }
        if !readiness.adapter_on_gpu {
            need_gb += spec.model.adapter_gb;
        }
        if !readiness.kernel_on_gpu {
            need_gb += spec.model.kernel_gb;
        }
        if !readiness.cuda_context {
            need_gb += params::CUDA_CONTEXT_GB;
        }

        if self.cluster.gpu(gpu).free_gb() < need_gb {
            let spill = self.cluster_spill_target(gpu);
            let plan = self.policies.offload.try_free(
                &mut self.cluster,
                &mut self.registry,
                gpu,
                need_gb,
                &[f],
                &self.functions,
                &self.rates,
                spill,
            );
            match plan {
                Some(plan) => {
                    self.stats.offload_events += 1;
                    self.stats.offloaded_gb += plan.freed_gb;
                    if self.cluster.gpu(gpu).free_gb() < need_gb {
                        // Even full eviction can't fit: shrink the batch.
                        let kv_free = self.cluster.gpu(gpu).free_gb()
                            - (need_gb - spec.model.kv_per_request_gb * want as f64);
                        let fit = (kv_free / spec.model.kv_per_request_gb).floor() as i64;
                        if fit < 1 {
                            return false;
                        }
                    }
                }
                None => {
                    // Blocking policy: wait until completions free memory.
                    let kv_free = self.cluster.gpu(gpu).free_gb()
                        - (need_gb - spec.model.kv_per_request_gb * want as f64);
                    if (kv_free / spec.model.kv_per_request_gb).floor() < 1.0 {
                        return false;
                    }
                }
            }
        }

        // Final batch size bounded by what actually fits.
        let fixed_gb = need_gb - spec.model.kv_per_request_gb * want as f64;
        let kv_budget = self.cluster.gpu(gpu).free_gb() - fixed_gb;
        let cap = (kv_budget / spec.model.kv_per_request_gb).floor().max(0.0) as usize;
        if cap == 0 {
            return false;
        }
        let taken = self.queues[f].take_batch(cap.min(want));
        debug_assert!(!taken.is_empty());
        let reqs: Vec<Request> = taken
            .iter()
            .map(|q| self.requests[self.request_index[&q.request]].clone())
            .collect();
        let b = reqs.len();

        // Mutate ledgers: make everything resident, reserve KV.
        let batch_id = self.next_batch;
        self.next_batch += 1;
        let mut load_phases = self.make_resident(f, &spec, gpu, readiness);
        let kv_gb = spec.model.kv_per_request_gb * b as f64;
        self.cluster
            .gpu_mut(gpu)
            .reserve_kv(batch_id, kv_gb)
            .expect("kv sized to fit");
        let attached = if self.cfg.backbone_sharing {
            self.registry
                .attach(&mut self.cluster, spec.model.name, gpu, f)
                .is_ok()
        } else {
            false
        };

        // §4.2: batching "avoid[s] creating new instances". A dispatch
        // while this function already has in-flight batches forces the
        // platform to scale out a NEW process instance: it pays its own
        // CUDA context plus per-context kernel handles (contexts are
        // per-process; pre-loaded artifacts shortcut the JIT but not the
        // context). This is what makes no-batching (NAB#1) slow under
        // concurrency even when everything is pre-loaded.
        let concurrent = self.batches.values().any(|b| b.function == f);
        if concurrent && !self.cfg.serverful {
            *load_phases.entry(Phase::ContainerInit).or_insert(0.0) +=
                params::CUDA_CONTEXT_INIT_S;
            *load_phases.entry(Phase::KernelCompile).or_insert(0.0) +=
                self.policies.preload.scaleout_kernel_s(f, &spec.model);
        }

        let total_load: f64 = load_phases.values().sum();
        if total_load > 0.0 {
            self.stats.cold_dispatches += 1;
        } else {
            self.stats.warm_dispatches += 1;
        }
        self.batches.insert(
            batch_id,
            Batch {
                function: f,
                gpu,
                requests: reqs,
                load_phases,
                t_dispatch: self.now,
                t_exec_start: 0.0,
                prefill_wall: 0.0,
                state: BatchState::Loading,
                kv_gb,
                attached_backbone: attached,
            },
        );
        self.events.push(self.now + total_load, EventKind::LoadDone(batch_id));
        true
    }

    /// Locality-vs-contention trade (§3.1 challenge 3): the router prefers
    /// GPUs that already host the backbone, but when every host is
    /// congested and a colder GPU has room for another shared copy, pay
    /// the one-time replica load — all later functions of this model
    /// attach to it for free.
    pub(super) fn maybe_replicate(&self, spec: &FunctionSpec, routed: GpuId) -> GpuId {
        if !self.cfg.backbone_sharing {
            return routed;
        }
        let contention = self.execs[&routed].contention();
        if contention < 2 {
            return routed;
        }
        let need = spec.model.gpu_resident_gb() + spec.model.kv_per_request_gb;
        self.cluster
            .gpu_ids()
            .into_iter()
            .filter(|&g| {
                self.execs[&g].contention() == 0 && self.cluster.gpu(g).free_gb() >= need
            })
            .max_by(|&a, &b| {
                self.cluster
                    .gpu(a)
                    .free_gb()
                    .partial_cmp(&self.cluster.gpu(b).free_gb())
                    .unwrap()
            })
            .unwrap_or(routed)
    }

    pub(super) fn cluster_spill_target(&self, gpu: GpuId) -> Option<ContainerId> {
        self.cluster
            .nodes
            .get(gpu.node)
            .and_then(|n| n.containers.first())
            .map(|c| c.id)
    }

    /// Make all artifacts of `f` resident on `gpu`, returning the phase →
    /// latency map for whatever had to be loaded (§6.3 breakdown). The
    /// preload policy prices the phases; the ledger mutations below are
    /// mechanism, identical for every policy.
    pub(super) fn make_resident(
        &mut self,
        f: usize,
        spec: &FunctionSpec,
        gpu: GpuId,
        ready: Readiness,
    ) -> BTreeMap<Phase, f64> {
        let m = &spec.model;
        // A pre-warmed instance (policy-staged kernels + CUDA context) is
        // as good as a keep-alive-warm one — the §6.3 claim that fully
        // pre-loaded cold starts run at warm-start speed.
        let warm_instance = self.policies.preload.prewarmed(ready)
            || (self.keepalive.is_warm(f, self.now) && ready.cuda_context);
        let container_has = |kind: ArtifactKind| {
            self.cluster
                .container_ids()
                .iter()
                .any(|&c| self.cluster.container(c).has(f, kind))
        };
        // Backbone staging copies are per-model, not per-function: any
        // function of the same model can read the host-RAM copy.
        let container_has_model_backbone = {
            let same_model: Vec<usize> = self
                .functions
                .iter()
                .filter(|s| s.model.name == m.name)
                .map(|s| s.id)
                .collect();
            self.cluster.container_ids().iter().any(|&c| {
                same_model
                    .iter()
                    .any(|&fid| self.cluster.container(c).has(fid, ArtifactKind::Backbone))
            })
        };
        let query = LoadQuery {
            function: f,
            model: m,
            ready,
            warm_instance,
            container_has_library: container_has(ArtifactKind::Library),
            container_has_adapter: container_has(ArtifactKind::Adapter),
            container_has_own_backbone: container_has(ArtifactKind::Backbone),
            container_has_model_backbone,
        };
        let phases = self.policies.preload.load_phases(&query);

        // Ledger mutations, driven by readiness alone.
        if !ready.backbone_on_gpu {
            if self.cfg.backbone_sharing {
                self.registry
                    .load(&mut self.cluster, m.name, m.weights_gb, gpu)
                    .expect("sized in dispatch");
            } else {
                self.cluster
                    .gpu_mut(gpu)
                    .place_artifact(f, ArtifactKind::Backbone, m.weights_gb)
                    .expect("sized in dispatch");
            }
        }
        if !ready.adapter_on_gpu {
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::Adapter, m.adapter_gb)
                .expect("sized in dispatch");
        }
        if !ready.kernel_on_gpu {
            self.cluster
                .gpu_mut(gpu)
                .place_artifact(f, ArtifactKind::CudaKernel, m.kernel_gb)
                .expect("sized in dispatch");
        }
        if !ready.cuda_context {
            self.cluster
                .gpu_mut(gpu)
                .create_cuda_context(f)
                .expect("sized in dispatch");
        }
        phases
    }

    // ------------------------------------------------------- exec events

    pub(super) fn on_load_done(&mut self, batch_id: u64) {
        let (gpu, f, b) = {
            let batch = self.batches.get_mut(&batch_id).expect("batch exists");
            batch.state = BatchState::Prefill;
            batch.t_exec_start = self.now;
            (batch.gpu, batch.function, batch.requests.len())
        };
        let work = self.spec(f).model.prefill_s(b);
        let exec = self.execs.get_mut(&gpu).unwrap();
        exec.add(self.now, batch_id, work);
        self.schedule_tick(gpu);
    }

    pub(super) fn schedule_tick(&mut self, gpu: GpuId) {
        let exec = &self.execs[&gpu];
        if let Some((_, t)) = exec.next_completion() {
            let v = exec.version;
            self.events.push(t.max(self.now), EventKind::GpuTick(gpu, v));
        }
    }

    pub(super) fn on_gpu_tick(&mut self, gpu: GpuId, version: u64) {
        if self.execs[&gpu].version != version {
            return; // stale
        }
        let finished = self.execs.get_mut(&gpu).unwrap().finished_at(self.now);
        for id in finished {
            self.on_job_done(id);
        }
        self.schedule_tick(gpu);
    }

    pub(super) fn on_job_done(&mut self, batch_id: u64) {
        let state = self.batches[&batch_id].state;
        match state {
            BatchState::Prefill => {
                let (gpu, f, b, max_out) = {
                    let batch = self.batches.get_mut(&batch_id).unwrap();
                    batch.prefill_wall = self.now - batch.t_exec_start;
                    batch.state = BatchState::Decode;
                    (
                        batch.gpu,
                        batch.function,
                        batch.requests.len(),
                        batch.requests.iter().map(|r| r.output_tokens).max().unwrap(),
                    )
                };
                let work = self.spec(f).model.tpot_at(b) * max_out as f64;
                let exec = self.execs.get_mut(&gpu).unwrap();
                exec.add_weighted(
                    self.now,
                    batch_id,
                    work,
                    crate::sim::exec::DECODE_WEIGHT,
                );
                self.schedule_tick(gpu);
                // Prefill slot freed: queues waiting on this GPU may go.
                self.try_dispatch_all(None);
            }
            BatchState::Decode => self.finalize_batch(batch_id),
            BatchState::Loading => unreachable!("loading batches are not exec jobs"),
        }
    }

    pub(super) fn finalize_batch(&mut self, batch_id: u64) {
        let batch = self.batches.remove(&batch_id).expect("batch exists");
        let f = batch.function;
        let b = batch.requests.len();
        let decode_start = batch.t_exec_start + batch.prefill_wall;
        let decode_wall = self.now - decode_start;
        let max_out = batch
            .requests
            .iter()
            .map(|r| r.output_tokens)
            .max()
            .unwrap()
            .max(1) as f64;

        for r in &batch.requests {
            let mut phases = batch.load_phases.clone();
            let queue_wait = batch.t_dispatch - r.arrival_s;
            *phases.entry(Phase::Queue).or_insert(0.0) += queue_wait.max(0.0);
            phases.insert(Phase::Prefill, batch.prefill_wall);
            // Requests stop decoding at their own length; wall time scales
            // proportionally under processor sharing.
            let own_decode = decode_wall * r.output_tokens as f64 / max_out;
            phases.insert(Phase::Decode, own_decode);
            let tpot = own_decode / r.output_tokens.max(1) as f64;
            let outcome: RequestOutcome =
                crate::metrics::outcome_from_phases(r, phases, tpot, b);
            self.metrics.record(outcome);
        }

        // Release resources.
        self.cluster.gpu_mut(batch.gpu).release_kv(batch_id);
        if batch.attached_backbone {
            let model = self.spec(f).model.name.to_string();
            let _ = self
                .registry
                .detach(&mut self.cluster, &crate::sharing::IpcHandle {
                    model,
                    gpu: batch.gpu,
                    function: f,
                });
        }
        // Keep-alive (serverless) and wakeup for its expiry.
        if !self.cfg.serverful {
            self.keepalive.touch(f, self.now);
            let t = self.now + self.keepalive.window_s;
            if t.is_finite() {
                self.events.push(t, EventKind::KeepaliveCheck);
            }
        }
        // Memory freed: retry blocked + any dispatchable queues.
        self.blocked.clear();
        self.try_dispatch_all(None);
    }
}
