//! Zone-sharded execution: one [`Engine`] per cluster zone, coupled only
//! through conservative time windows.
//!
//! # Model
//!
//! A fleet-scale cluster is partitioned into `Z` *zones* — disjoint sets
//! of nodes with their own GPUs, containers, and coordinator state.
//! Functions are assigned round-robin: zone `z` owns every global
//! function `f` with `f % Z == z`, renumbered to the dense local id
//! `f / Z` (so `global = zone + local·Z` round-trips). Each zone is a
//! complete, independent simulation: its own timing wheel, dispatch
//! state, billing arenas, and RNG stream seeded identically from the run
//! seed — routing, batching, and keep-alive never cross a zone boundary.
//!
//! The only inter-zone coupling is an *advisory* one: which backbone
//! models the other zones currently host ([`Engine::set_peer_models`]).
//! A zone whose cold backbone load finds the model resident in a peer
//! zone streams it over the datacenter fabric instead of remote storage
//! (`params::CROSS_ZONE_BACKBONE_FACTOR`). Because that hint changes
//! event *durations* but never creates or reorders events across zones,
//! zones only need to agree on *when* the hint is refreshed — which is
//! what the conservative window protocol pins down.
//!
//! # Window protocol
//!
//! Time advances in fixed windows of [`ZONE_WINDOW_S`]. Every zone
//! simulates window `k` (`t ≤ k·W`) to completion, then all zones
//! exchange their hosted-model sets at the barrier; each zone installs
//! the union of its peers' sets and proceeds to window `k+1`. The run
//! ends at the first boundary where every zone's event queue is empty
//! (queues drain monotonically across a barrier: installing peer models
//! schedules nothing).
//!
//! Determinism: within a window a zone touches only its own state, so
//! thread scheduling cannot reorder anything observable; at a barrier
//! every zone reads the same published snapshots. Hence
//! [`Mode::Parallel`] is *bit-identical* to [`Mode::Sequential`] — the
//! single-threaded differential oracle that runs the very same window
//! schedule one zone at a time. Tests assert this equality on full
//! output fingerprints (outcomes, cost integrals, counters, bill
//! series).
//!
//! With `Z = 1` the peer set is always empty and the window chopping is
//! pure `step_until` slicing, which never reorders timing-wheel pops —
//! so a one-zone run is bit-identical to the plain [`Engine::run_full`]
//! path (also asserted in tests).

use std::collections::BTreeSet;
use std::sync::{Barrier, Mutex};

use super::config::SystemConfig;
use super::engine::{Engine, Workload};
use super::observe::{BillSeries, RunOutput};
use crate::cluster::Cluster;
use crate::cost::CostTracker;
use crate::metrics::{RunMetrics, RunStats};

/// Conservative synchronization window (simulated seconds). Large enough
/// that barrier overhead is negligible against the ~10⁴ events a busy
/// zone processes per window; small enough that the cross-zone
/// hosted-model hint stays fresh relative to keep-alive timescales
/// (`coordinator::keepalive::DEFAULT_KEEPALIVE_S`).
pub const ZONE_WINDOW_S: f64 = 10.0;

/// How the zone engines are driven. Both modes execute the identical
/// window schedule and must produce bit-identical output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    /// All zones on the calling thread, one after another per window —
    /// the differential oracle for the parallel path.
    Sequential,
    /// One OS thread per zone, synchronized with a barrier per window.
    Parallel,
}

/// What one zone publishes at a window boundary.
#[derive(Default)]
struct Board {
    hosted: BTreeSet<&'static str>,
    drained: bool,
}

/// Run `workload` sharded across `clusters.len()` zones and merge the
/// per-zone outputs into one [`RunOutput`] (global function ids
/// restored, cost and counters summed, bill-series buckets added
/// elementwise, `duration_s` the max over zones).
pub fn run_zones(
    cfg: &SystemConfig,
    clusters: Vec<Cluster>,
    workload: Workload,
    seed: u64,
    mode: Mode,
    bill_timing: bool,
    series_bucket_s: Option<f64>,
) -> RunOutput {
    let zones = clusters.len();
    assert!(zones >= 1, "run_zones needs at least one zone");
    let shards = split_workload(&workload, zones);
    let zone_inputs: Vec<(Cluster, Workload)> = clusters.into_iter().zip(shards).collect();
    let outputs = match mode {
        Mode::Sequential => run_sequential(cfg, zone_inputs, seed, bill_timing, series_bucket_s),
        // A single zone never waits on a barrier; skip the thread.
        Mode::Parallel if zones == 1 => {
            run_sequential(cfg, zone_inputs, seed, bill_timing, series_bucket_s)
        }
        Mode::Parallel => run_parallel(cfg, zone_inputs, seed, bill_timing, series_bucket_s),
    };
    merge(outputs, zones)
}

/// Round-robin shard of the global workload: zone `z` gets every
/// function with `f % zones == z` under the dense local id `f / zones`,
/// with its requests and mean-rate entry remapped alongside. Request
/// ids and arrival order are preserved (a stable filter of a
/// time-ordered stream stays time-ordered).
fn split_workload(w: &Workload, zones: usize) -> Vec<Workload> {
    let mut shards: Vec<Workload> = (0..zones)
        .map(|_| Workload {
            functions: Vec::new(),
            requests: Vec::new(),
            duration_s: w.duration_s,
            rates: Vec::new(),
        })
        .collect();
    for f in &w.functions {
        let shard = &mut shards[f.id % zones];
        let mut local = f.clone();
        local.id = f.id / zones;
        assert_eq!(
            shard.functions.len(),
            local.id,
            "workload function ids must be dense from 0"
        );
        shard.rates.push(w.rates[f.id]);
        shard.functions.push(local);
    }
    for r in &w.requests {
        let mut req = r.clone();
        req.function = r.function / zones;
        shards[r.function % zones].requests.push(req);
    }
    shards
}

fn build_engine(
    cfg: &SystemConfig,
    cluster: Cluster,
    shard: Workload,
    seed: u64,
    bill_timing: bool,
    series_bucket_s: Option<f64>,
) -> Engine {
    let mut e = Engine::new(cfg.clone(), cluster, shard, seed);
    if bill_timing {
        e.set_bill_timing(true);
    }
    if let Some(bucket_s) = series_bucket_s {
        e.enable_bill_series(bucket_s);
    }
    e
}

/// Union of every peer's hosted-model set, excluding zone `me`.
fn peer_union(boards: &[BTreeSet<&'static str>], me: usize) -> BTreeSet<&'static str> {
    let mut peers = BTreeSet::new();
    for (z, hosted) in boards.iter().enumerate() {
        if z != me {
            peers.extend(hosted.iter().copied());
        }
    }
    peers
}

/// The differential oracle: the exact window schedule of the parallel
/// path, executed zone-by-zone on one thread.
fn run_sequential(
    cfg: &SystemConfig,
    zone_inputs: Vec<(Cluster, Workload)>,
    seed: u64,
    bill_timing: bool,
    series_bucket_s: Option<f64>,
) -> Vec<RunOutput> {
    let mut engines: Vec<Engine> = zone_inputs
        .into_iter()
        .map(|(cluster, shard)| {
            build_engine(cfg, cluster, shard, seed, bill_timing, series_bucket_s)
        })
        .collect();
    let mut boundary = ZONE_WINDOW_S;
    loop {
        for e in engines.iter_mut() {
            e.step_until(boundary);
        }
        let boards: Vec<BTreeSet<&'static str>> =
            engines.iter().map(Engine::hosted_models).collect();
        let all_done = engines.iter().all(|e| e.event_queue_len() == 0);
        for (z, e) in engines.iter_mut().enumerate() {
            e.set_peer_models(peer_union(&boards, z));
        }
        if all_done {
            break;
        }
        boundary += ZONE_WINDOW_S;
    }
    engines.into_iter().map(Engine::finish_full).collect()
}

/// One thread per zone. Engines are built *inside* their thread (policy
/// objects are not `Send`; only plain config/cluster/workload data
/// crosses the spawn). Two barrier waits per window: publish → read, and
/// read → next window (so a fast zone cannot overwrite a board a slow
/// peer has not read yet). Every thread reads the same published
/// snapshot, so the termination decision is identical across threads.
fn run_parallel(
    cfg: &SystemConfig,
    zone_inputs: Vec<(Cluster, Workload)>,
    seed: u64,
    bill_timing: bool,
    series_bucket_s: Option<f64>,
) -> Vec<RunOutput> {
    let zones = zone_inputs.len();
    let boards: Vec<Mutex<Board>> = (0..zones).map(|_| Mutex::new(Board::default())).collect();
    let barrier = Barrier::new(zones);
    std::thread::scope(|scope| {
        let handles: Vec<_> = zone_inputs
            .into_iter()
            .enumerate()
            .map(|(me, (cluster, shard))| {
                let boards = &boards;
                let barrier = &barrier;
                scope.spawn(move || {
                    let mut e =
                        build_engine(cfg, cluster, shard, seed, bill_timing, series_bucket_s);
                    let mut boundary = ZONE_WINDOW_S;
                    loop {
                        e.step_until(boundary);
                        {
                            let mut board = boards[me].lock().unwrap();
                            board.hosted = e.hosted_models();
                            board.drained = e.event_queue_len() == 0;
                        }
                        barrier.wait();
                        let mut snapshot = Vec::with_capacity(zones);
                        let mut all_done = true;
                        for slot in boards.iter() {
                            let board = slot.lock().unwrap();
                            all_done &= board.drained;
                            snapshot.push(board.hosted.clone());
                        }
                        e.set_peer_models(peer_union(&snapshot, me));
                        barrier.wait();
                        if all_done {
                            break;
                        }
                        boundary += ZONE_WINDOW_S;
                    }
                    e.finish_full()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("zone thread panicked"))
            .collect()
    })
}

/// Fold per-zone outputs into one global [`RunOutput`]. Outcomes are
/// remapped back to global function ids (`zone + local·zones`) and
/// concatenated in zone order — a deterministic order, though not
/// globally arrival-sorted; all downstream consumers aggregate.
fn merge(mut outputs: Vec<RunOutput>, zones: usize) -> RunOutput {
    if zones == 1 {
        return outputs.pop().expect("one zone produces one output");
    }
    let mut metrics = RunMetrics::default();
    let mut cost = CostTracker::default();
    let mut stats = RunStats::default();
    let mut series: Option<BillSeries> = None;
    for (zone, out) in outputs.into_iter().enumerate() {
        metrics.duration_s = metrics.duration_s.max(out.metrics.duration_s);
        // Failed requests leave no outcome — carry the counters across
        // zones explicitly (with function ids restored to global) so
        // goodput and SLO attainment stay global.
        metrics.failed += out.metrics.failed;
        for (local, n) in out.metrics.failed_by_function {
            *metrics.failed_by_function.entry(zone + local * zones).or_insert(0) += n;
        }
        for mut o in out.metrics.outcomes {
            o.function = zone + o.function * zones;
            metrics.outcomes.push(o);
        }
        cost.merge(&out.cost);
        stats.merge(&out.stats);
        if let Some(s) = out.bill_series {
            series = Some(match series.take() {
                None => s,
                Some(acc) => merge_series(acc, s),
            });
        }
    }
    RunOutput {
        metrics,
        cost,
        stats,
        bill_series: series,
    }
}

/// Elementwise sum of two zones' bill series (same bucket width by
/// construction; the shorter series is zero-extended).
fn merge_series(mut a: BillSeries, b: BillSeries) -> BillSeries {
    assert_eq!(
        a.bucket_s.to_bits(),
        b.bucket_s.to_bits(),
        "zones must sample the bill series on the same bucket"
    );
    if a.buckets.len() < b.buckets.len() {
        a.buckets.resize(b.buckets.len(), Default::default());
    }
    for (x, y) in a.buckets.iter_mut().zip(&b.buckets) {
        x.active_gb_s += y.active_gb_s;
        x.active_gpu_s += y.active_gpu_s;
        x.loading_gb_s += y.loading_gb_s;
        x.loading_gpu_s += y.loading_gpu_s;
        x.idle_warm_gb_s += y.idle_warm_gb_s;
        x.idle_warm_gpu_s += y.idle_warm_gpu_s;
        x.idle_cold_gb_s += y.idle_cold_gb_s;
        x.idle_cold_gpu_s += y.idle_cold_gpu_s;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::artifact::{FunctionSpec, ModelProfile};
    use crate::trace::{self, Pattern, Request, TraceSpec};

    fn workload(n_fns: usize, rate: f64, dur: f64) -> Workload {
        let functions: Vec<FunctionSpec> = (0..n_fns)
            .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i))
            .collect();
        let traces: Vec<Vec<Request>> = (0..n_fns)
            .map(|i| TraceSpec::new(i, Pattern::Bursty, rate, 9 + i as u64).generate(dur))
            .collect();
        Workload {
            functions,
            requests: trace::merge(traces),
            duration_s: dur,
            rates: vec![rate; n_fns],
        }
    }

    /// Bit-exact fingerprint: `Debug` for `f64` prints the shortest
    /// uniquely-round-tripping decimal, so equal strings ⇔ equal bits
    /// (wall-clock timing stays off in these tests, so every field is
    /// deterministic).
    fn fp(o: &RunOutput) -> String {
        format!("{:?} {:?} {:?} {:?}", o.metrics, o.cost, o.stats, o.bill_series)
    }

    #[test]
    fn split_remaps_functions_requests_and_rates() {
        let w = workload(5, 0.05, 300.0);
        let shards = split_workload(&w, 2);
        // Zone 0 owns {0, 2, 4}, zone 1 owns {1, 3}.
        assert_eq!(shards[0].functions.len(), 3);
        assert_eq!(shards[1].functions.len(), 2);
        for (zone, s) in shards.iter().enumerate() {
            for (local, f) in s.functions.iter().enumerate() {
                assert_eq!(f.id, local, "local ids must be dense");
                // The clone keeps the global adapter id: recover the
                // global function id and check the rate moved with it.
                let global = zone + local * 2;
                assert_eq!(f.adapter_id, global);
                assert!((s.rates[local] - w.rates[global]).abs() < 1e-15);
            }
            assert!(s.requests.windows(2).all(|p| p[0].arrival_s <= p[1].arrival_s));
        }
        let total: usize = shards.iter().map(|s| s.requests.len()).sum();
        assert_eq!(total, w.requests.len());
    }

    #[test]
    fn one_zone_is_bit_identical_to_the_plain_engine() {
        // zones = 1 must be a pure refactor: window-chopped stepping and
        // an always-empty peer set change nothing, in either mode.
        let cfg = SystemConfig::serverless_lora();
        let w = workload(4, 0.05, 1200.0);
        let plain = {
            let mut e = Engine::new(cfg.clone(), Cluster::new(2, 2, 4), w.clone(), 1);
            e.enable_bill_series(300.0);
            e.run_full()
        };
        for mode in [Mode::Sequential, Mode::Parallel] {
            let out = run_zones(
                &cfg,
                vec![Cluster::new(2, 2, 4)],
                w.clone(),
                1,
                mode,
                false,
                Some(300.0),
            );
            assert_eq!(fp(&plain), fp(&out), "{mode:?} diverged at zones=1");
        }
    }

    #[test]
    fn parallel_matches_sequential_oracle_multi_seed() {
        // Thread scheduling must be unobservable: the parallel run is
        // bit-identical to the single-threaded oracle and to itself.
        let cfg = SystemConfig::serverless_lora();
        let zones = || vec![Cluster::new(1, 2, 4), Cluster::new(1, 2, 4)];
        for seed in [1u64, 7, 23] {
            let w = workload(8, 0.05, 1200.0);
            let run = |mode| run_zones(&cfg, zones(), w.clone(), seed, mode, false, Some(300.0));
            let oracle = fp(&run(Mode::Sequential));
            assert_eq!(oracle, fp(&run(Mode::Parallel)), "seed {seed}");
            assert_eq!(oracle, fp(&run(Mode::Parallel)), "seed {seed} (rerun)");
        }
    }

    #[test]
    fn parallel_matches_sequential_under_domain_faults_multi_seed() {
        // The tentpole determinism lock: correlated node/zone outages and
        // degraded-mode episodes inside every zone engine must leave
        // Mode::Parallel bit-identical to the single-threaded oracle —
        // fault draws ride each zone's own injector stream, so thread
        // scheduling has nothing to reorder. Conservation (arrivals ==
        // completed + failed) must hold globally with whole zones dying.
        use crate::sim::fault::{DegradeSpec, DomainLevel, DomainSpec, FaultSpec};
        let cfg = SystemConfig::serverless_lora().with_faults(FaultSpec {
            mtbf_s: 400.0,
            mttr_s: 20.0,
            domains: Some(DomainSpec {
                node: Some(DomainLevel { mtbf_s: 300.0, mttr_s: 25.0 }),
                zone: Some(DomainLevel { mtbf_s: 600.0, mttr_s: 30.0 }),
            }),
            degrade: Some(DegradeSpec {
                mtbf_s: 200.0,
                duration_s: 40.0,
                factor_min: 2.0,
                factor_max: 4.0,
            }),
            ..FaultSpec::default()
        });
        let zones = || vec![Cluster::new(1, 2, 4), Cluster::new(1, 2, 4)];
        let mut fired = false;
        for seed in [1u64, 7, 23] {
            let w = workload(8, 0.05, 1200.0);
            let n = w.requests.len();
            let run = |mode| run_zones(&cfg, zones(), w.clone(), seed, mode, false, Some(300.0));
            let seq = run(Mode::Sequential);
            assert_eq!(fp(&seq), fp(&run(Mode::Parallel)), "seed {seed}");
            assert_eq!(
                seq.metrics.outcomes.len() + seq.metrics.failed as usize,
                n,
                "conservation across dying zones (seed {seed})"
            );
            assert_eq!(
                seq.metrics.failed_by_function.values().sum::<u64>(),
                seq.metrics.failed,
                "per-function failure counts must sum to the total (seed {seed})"
            );
            fired |= seq.stats.zone_outages > 0 && seq.stats.node_outages > 0;
        }
        assert!(fired, "no seed exercised both domain levels");
    }

    #[test]
    fn merge_restores_global_ids_and_conserves_requests() {
        let cfg = SystemConfig::serverless_lora();
        let w = workload(5, 0.05, 600.0);
        let out = run_zones(
            &cfg,
            vec![Cluster::new(1, 2, 4), Cluster::new(1, 2, 4)],
            w.clone(),
            1,
            Mode::Parallel,
            false,
            None,
        );
        assert_eq!(out.metrics.outcomes.len(), w.requests.len());
        let mut want = vec![0usize; 5];
        for r in &w.requests {
            want[r.function] += 1;
        }
        let mut got = vec![0usize; 5];
        for o in &out.metrics.outcomes {
            got[o.function] += 1;
        }
        assert_eq!(got, want, "per-global-function outcome counts");
        assert!(out.cost.total_usd() > 0.0);
        assert!(out.stats.events_processed > 0);
    }
}
