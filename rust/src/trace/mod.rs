//! Workload / trace generation (paper §6.1).
//!
//! The paper drives its evaluation with Azure Functions production traces,
//! classified purely by the coefficient of variation (CoV) of request
//! inter-arrival times: Predictable (CoV ≤ 1), Normal (1 < CoV ≤ 4),
//! Bursty (CoV > 4).  We reproduce exactly that statistic with a renewal
//! process whose inter-arrival law is chosen per class:
//!
//! * Predictable — Gamma with shape 1/CoV² > 1 (sub-exponential spread);
//! * Normal      — hyper-exponential ON/OFF mixture tuned to the target CoV;
//! * Bursty      — ON/OFF bursts: long idle gaps, tight in-burst spacing —
//!                 the 34.6× peak/valley swing the Azure LLM traces show.
//!
//! Prompt/output token lengths follow a GSM8K-like distribution (§6.1:
//! GSM8K prompts; chain-of-thought-length answers).

use crate::util::rng::Pcg64;

/// Arrival-pattern class, by inter-arrival CoV (paper Fig. 5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pattern {
    /// CoV ≤ 1
    Predictable,
    /// 1 < CoV ≤ 4
    Normal,
    /// CoV > 4
    Bursty,
}

impl Pattern {
    pub const ALL: [Pattern; 3] =
        [Pattern::Predictable, Pattern::Normal, Pattern::Bursty];

    pub fn name(self) -> &'static str {
        match self {
            Pattern::Predictable => "Predictable",
            Pattern::Normal => "Normal",
            Pattern::Bursty => "Bursty",
        }
    }

    /// The CoV band this class must land in (used by calibration tests
    /// and the fig5 bench).
    pub fn cov_band(self) -> (f64, f64) {
        match self {
            Pattern::Predictable => (0.0, 1.0),
            Pattern::Normal => (1.0, 4.0),
            Pattern::Bursty => (4.0, f64::INFINITY),
        }
    }

    /// Classify a target inter-arrival CoV into its pattern class — the
    /// paper's Fig. 5 rule, used by `fleet --cov-head/--cov-tail` to map
    /// a numeric CoV onto a generator class.
    pub fn for_cov(cov: f64) -> Pattern {
        if cov <= 1.0 {
            Pattern::Predictable
        } else if cov <= 4.0 {
            Pattern::Normal
        } else {
            Pattern::Bursty
        }
    }
}

/// One inference request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub function: usize,
    /// Arrival time, seconds from workload start.
    pub arrival_s: f64,
    /// Prompt length in tokens.
    pub prompt_tokens: usize,
    /// Number of tokens to generate.
    pub output_tokens: usize,
}

/// GSM8K-like prompt/answer length sampler. GSM8K problems average ≈60
/// tokens; chain-of-thought answers average ≈120 tokens with a long tail.
#[derive(Debug, Clone)]
pub struct GsmLengths;

impl GsmLengths {
    pub fn prompt(rng: &mut Pcg64) -> usize {
        (rng.lognormal(55.0, 0.35).round() as usize).clamp(8, 512)
    }

    pub fn output(rng: &mut Pcg64) -> usize {
        // Median ≈ 70 tokens, clamped tail: GSM8K chain-of-thought answers
        // are short; an unclamped tail would make one 500-token request
        // hold its whole batch hostage in the lock-step decode model
        // (real engines release finished requests iteration-by-iteration).
        (rng.lognormal(70.0, 0.35).round() as usize).clamp(16, 192)
    }
}

/// Generator for one function's arrival stream.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub function: usize,
    pub pattern: Pattern,
    /// Long-run mean request rate (req/s).
    pub rate: f64,
    pub seed: u64,
}

impl TraceSpec {
    pub fn new(function: usize, pattern: Pattern, rate: f64, seed: u64) -> Self {
        TraceSpec { function, pattern, rate, seed }
    }

    /// Generate all requests in [0, duration_s).
    pub fn generate(&self, duration_s: f64) -> Vec<Request> {
        let mut rng = Pcg64::with_stream(self.seed, 0x7ace ^ self.function as u64);
        let mut out = Vec::new();
        let mut t = 0.0;
        let mean_gap = 1.0 / self.rate;
        let mut id = (self.function as u64) << 40;

        match self.pattern {
            Pattern::Predictable => {
                // Gamma renewal, CoV ≈ 0.5 ⇒ shape 4.
                let shape = 4.0;
                let scale = mean_gap / shape;
                while t < duration_s {
                    t += rng.gamma(shape, scale);
                    if t >= duration_s {
                        break;
                    }
                    out.push(self.request(&mut rng, &mut id, t));
                }
            }
            Pattern::Normal => {
                // Hyper-exponential H2 (balanced means): CoV² = 2/p − 1 with
                // branch probability p of the "slow" branch. Target CoV ≈ 2.5
                // ⇒ p = 2/(1+CoV²) ≈ 0.275.
                let target_cov2 = 2.5f64 * 2.5;
                let p = 2.0 / (1.0 + target_cov2);
                // Balanced-means H2: branch i has rate λ_i = 2 p_i / mean.
                let r1 = 2.0 * p / mean_gap;
                let r2 = 2.0 * (1.0 - p) / mean_gap;
                while t < duration_s {
                    let gap = if rng.f64() < p { rng.exp(r1) } else { rng.exp(r2) };
                    t += gap;
                    if t >= duration_s {
                        break;
                    }
                    out.push(self.request(&mut rng, &mut id, t));
                }
            }
            Pattern::Bursty => {
                // ON/OFF: bursts of k requests with tight spacing, separated
                // by long idle gaps. Parameters chosen so the overall mean
                // rate is preserved and CoV lands > 4.
                let burst_size_mean = 12.0;
                // In-burst spacing is near-concurrent regardless of the
                // mean rate: Azure bursts are API fan-outs that land
                // within tens of milliseconds.
                let tight = (mean_gap / 40.0).min(0.05);
                // idle gap so that total mean matches `rate`:
                // E[T_burst_cycle] = burst_size · mean_gap.
                let idle = burst_size_mean * mean_gap
                    - (burst_size_mean - 1.0) * tight;
                while t < duration_s {
                    t += rng.exp(1.0 / idle);
                    let k = 1 + rng.below(2 * burst_size_mean as usize - 1);
                    for _ in 0..k {
                        if t >= duration_s {
                            break;
                        }
                        out.push(self.request(&mut rng, &mut id, t));
                        t += rng.exp(1.0 / tight);
                    }
                }
            }
        }
        out
    }

    fn request(&self, rng: &mut Pcg64, id: &mut u64, t: f64) -> Request {
        *id += 1;
        Request {
            id: *id,
            function: self.function,
            arrival_s: t,
            prompt_tokens: GsmLengths::prompt(rng),
            output_tokens: GsmLengths::output(rng),
        }
    }
}

/// Merge several functions' traces into one time-ordered stream.
pub fn merge(traces: Vec<Vec<Request>>) -> Vec<Request> {
    let mut all: Vec<Request> = traces.into_iter().flatten().collect();
    all.sort_by(|a, b| a.arrival_s.total_cmp(&b.arrival_s));
    all
}

/// Inter-arrival CoV of a stream (the classification statistic).
pub fn stream_cov(reqs: &[Request]) -> f64 {
    if reqs.len() < 3 {
        return 0.0;
    }
    let gaps: Vec<f64> = reqs
        .windows(2)
        .map(|w| w[1].arrival_s - w[0].arrival_s)
        .collect();
    crate::util::stats::cov(&gaps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gen(pattern: Pattern) -> Vec<Request> {
        TraceSpec::new(0, pattern, 0.5, 42).generate(4.0 * 3600.0)
    }

    #[test]
    fn covs_land_in_their_bands() {
        for p in Pattern::ALL {
            let reqs = gen(p);
            let cov = stream_cov(&reqs);
            let (lo, hi) = p.cov_band();
            assert!(
                cov > lo && cov <= hi.min(1e9),
                "{}: cov={cov} not in ({lo}, {hi})",
                p.name()
            );
        }
    }

    #[test]
    fn mean_rate_approximately_preserved() {
        for p in Pattern::ALL {
            let reqs = gen(p);
            let rate = reqs.len() as f64 / (4.0 * 3600.0);
            assert!(
                (rate - 0.5).abs() < 0.2,
                "{}: rate={rate}",
                p.name()
            );
        }
    }

    #[test]
    fn arrivals_sorted_and_in_range() {
        for p in Pattern::ALL {
            let reqs = gen(p);
            for w in reqs.windows(2) {
                assert!(w[1].arrival_s >= w[0].arrival_s);
            }
            assert!(reqs.iter().all(|r| r.arrival_s < 4.0 * 3600.0));
        }
    }

    #[test]
    fn for_cov_matches_bands() {
        for p in Pattern::ALL {
            let (lo, hi) = p.cov_band();
            let probe = if hi.is_finite() { (lo + hi) / 2.0 } else { lo + 3.0 };
            assert_eq!(Pattern::for_cov(probe), p);
        }
        assert_eq!(Pattern::for_cov(1.0), Pattern::Predictable); // boundary
        assert_eq!(Pattern::for_cov(4.0), Pattern::Normal);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TraceSpec::new(1, Pattern::Bursty, 1.0, 7).generate(600.0);
        let b = TraceSpec::new(1, Pattern::Bursty, 1.0, 7).generate(600.0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival_s, y.arrival_s);
            assert_eq!(x.prompt_tokens, y.prompt_tokens);
        }
    }

    #[test]
    fn lengths_in_gsm8k_like_range() {
        let reqs = gen(Pattern::Normal);
        let pm: f64 = reqs.iter().map(|r| r.prompt_tokens as f64).sum::<f64>()
            / reqs.len() as f64;
        let om: f64 = reqs.iter().map(|r| r.output_tokens as f64).sum::<f64>()
            / reqs.len() as f64;
        assert!((45.0..80.0).contains(&pm), "prompt mean {pm}");
        assert!((55.0..100.0).contains(&om), "output mean {om}");
    }

    #[test]
    fn merge_sorts_globally() {
        let a = TraceSpec::new(0, Pattern::Normal, 0.5, 1).generate(100.0);
        let b = TraceSpec::new(1, Pattern::Bursty, 0.5, 2).generate(100.0);
        let m = merge(vec![a.clone(), b.clone()]);
        assert_eq!(m.len(), a.len() + b.len());
        for w in m.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
    }
}
