//! Cross-language golden tests over the REAL runtime: the Rust PJRT
//! engine must reproduce the logits Python/JAX computed at AOT time for
//! every adapter, and the sharing/isolation contracts must hold on the
//! live data plane. Skipped (cleanly) when `make artifacts` has not run.
//! Compiled only with the `pjrt` feature (the runtime needs `xla`).
#![cfg(feature = "pjrt")]

use serverless_lora::runtime::{Engine, Manifest};

fn engine() -> Option<Engine> {
    let dir = Manifest::default_dir("llama-tiny");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Engine::load(dir).expect("engine loads"))
}

/// Mirror of python/compile/aot.py::golden_prompt's LCG.
fn golden_prompt(batch: usize, seq: usize, vocab: usize, adapter: usize) -> Vec<i32> {
    let mut state: u64 = 0x9E3779B9u64
        ^ (batch as u64 * 1000003 + seq as u64 * 101 + adapter as u64);
    let mut out = Vec::with_capacity(batch * seq);
    for _ in 0..batch * seq {
        state = (state.wrapping_mul(1664525).wrapping_add(1013904223)) % (1 << 32);
        out.push((state % vocab as u64) as i32);
    }
    out
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Every stored golden (one per adapter): prefill + one decode step match
/// Python bit-closely and agree on argmax.
#[test]
fn all_goldens_reproduce() {
    let Some(e) = engine() else { return };
    assert!(!e.manifest.goldens.is_empty());
    for g in &e.manifest.goldens {
        let inst = e.instance(g.adapter).unwrap();
        let prompt = golden_prompt(g.batch, g.seq, e.manifest.dims.vocab, g.adapter);
        let prompts: Vec<Vec<i32>> = prompt.chunks(g.seq).map(|c| c.to_vec()).collect();
        let (logits, mut kv) = e.prefill(&inst, &prompts).unwrap();
        for (i, expect) in g.prefill_logits_head.iter().enumerate() {
            let got = logits[0][i] as f64;
            assert!(
                (got - expect).abs() < 1e-3 * expect.abs().max(1.0),
                "adapter {}: prefill logit[{i}] {got} != {expect}",
                g.adapter
            );
        }
        for (row, &am) in g.prefill_argmax.iter().enumerate() {
            assert_eq!(argmax(&logits[row]), am, "adapter {} row {row}", g.adapter);
        }
        let next: Vec<i32> = logits.iter().map(|l| argmax(l) as i32).collect();
        let l2 = e.decode(&inst, &next, &mut kv).unwrap();
        for (i, expect) in g.decode_logits_head.iter().enumerate() {
            let got = l2[0][i] as f64;
            assert!(
                (got - expect).abs() < 2e-3 * expect.abs().max(1.0),
                "adapter {}: decode logit[{i}] {got} != {expect}",
                g.adapter
            );
        }
        for (row, &am) in g.decode_argmax.iter().enumerate() {
            assert_eq!(argmax(&l2[row]), am, "adapter {} decode row {row}", g.adapter);
        }
    }
}

/// §4.4 on the live data plane: hundreds of isolated instances share ONE
/// backbone buffer set; detaching returns the refcount to baseline.
#[test]
fn live_backbone_sharing_scales() {
    let Some(e) = engine() else { return };
    let base = e.backbone_refcount();
    let instances: Vec<_> = (0..64)
        .map(|i| e.instance(i % e.manifest.n_adapters).unwrap())
        .collect();
    assert_eq!(e.backbone_refcount(), base + 64);
    drop(instances);
    assert_eq!(e.backbone_refcount(), base);
}

/// Functions are isolated: concurrent generations with different adapters
/// over the shared backbone give each function its own (deterministic)
/// output — state never leaks across instances.
#[test]
fn live_isolation_across_adapters() {
    let Some(e) = engine() else { return };
    let prompt = vec![vec![3i32, 1, 4, 1, 5, 9, 2, 6]];
    let solo: Vec<Vec<i32>> = (0..e.manifest.n_adapters)
        .map(|a| {
            let inst = e.instance(a).unwrap();
            e.generate(&inst, &prompt, 5).unwrap().remove(0)
        })
        .collect();
    // Interleaved execution must reproduce the solo outputs exactly.
    let insts: Vec<_> = (0..e.manifest.n_adapters)
        .map(|a| e.instance(a).unwrap())
        .collect();
    for round in 0..2 {
        for (a, inst) in insts.iter().enumerate() {
            let out = e.generate(inst, &prompt, 5).unwrap().remove(0);
            assert_eq!(out, solo[a], "adapter {a} round {round} diverged");
        }
    }
    // And at least two adapters must behave differently.
    assert!(
        solo.windows(2).any(|w| w[0] != w[1]),
        "all adapters produced identical output: {solo:?}"
    );
}

/// KV-cache isolation: interleaving decode steps of two live batches from
/// different functions does not cross-contaminate their caches.
#[test]
fn live_kv_isolation_interleaved_decode() {
    let Some(e) = engine() else { return };
    let i0 = e.instance(0).unwrap();
    let i1 = e.instance(1).unwrap();
    let p0 = vec![vec![10i32; 8]];
    let p1 = vec![vec![20i32; 8]];
    // Reference: run each alone.
    let ref0 = e.generate(&i0, &p0, 4).unwrap();
    let ref1 = e.generate(&i1, &p1, 4).unwrap();
    // Interleaved: alternate decode steps.
    let (l0, mut kv0) = e.prefill(&i0, &p0).unwrap();
    let (l1, mut kv1) = e.prefill(&i1, &p1).unwrap();
    let mut t0 = vec![argmax(&l0[0]) as i32];
    let mut t1 = vec![argmax(&l1[0]) as i32];
    for _ in 1..4 {
        let n0 = e.decode(&i0, &[*t0.last().unwrap()], &mut kv0).unwrap();
        let n1 = e.decode(&i1, &[*t1.last().unwrap()], &mut kv1).unwrap();
        t0.push(argmax(&n0[0]) as i32);
        t1.push(argmax(&n1[0]) as i32);
    }
    assert_eq!(t0, ref0[0], "fn0 corrupted by interleaving");
    assert_eq!(t1, ref1[0], "fn1 corrupted by interleaving");
}

/// Engine profile sanity: compiling the artifact set is the "kernel JIT"
/// cost of this stack — it must be measured and nonzero.
#[test]
fn engine_profile_measured() {
    let Some(e) = engine() else { return };
    assert!(e.profile.compile_s > 0.0);
    assert!(e.profile.n_executables >= 4);
    assert_eq!(e.profile.backbone_bytes, e.manifest.dims.param_count * 4);
}
