//! Scenario-API integration tests: JSON round-trip ⇒ bit-identical
//! reruns, observer-hook accounting across a full simulation, and the
//! committed example files' validity.

use std::sync::{Arc, Mutex};

use serverless_lora::cluster::GpuId;
use serverless_lora::coordinator::policy::AggregateBillSample;
use serverless_lora::metrics::RequestOutcome;
use serverless_lora::scenario::{
    self, ClusterSpec, ScenarioSpec, SystemSpec, WorkloadSpec, SYSTEM_IDS,
};
use serverless_lora::sim::{BillClass, Engine, Observer, SystemConfig};
use serverless_lora::trace::Pattern;
use serverless_lora::util::json::Json;

fn tiny_cluster() -> ClusterSpec {
    ClusterSpec::Uniform { nodes: 1, gpus_per_node: 2, containers_per_node: 4, trim_gpus: None }
}

/// Satellite acceptance: build → serialize → parse → rerun must produce
/// **bit-identical** `RunMetrics` / `total_usd` for a family of specs
/// spanning every workload family that runs cheaply.
#[test]
fn json_roundtrip_reruns_bit_identical() {
    let specs = vec![
        ScenarioSpec::builder("rt-paper")
            .cluster(tiny_cluster())
            .workload(WorkloadSpec::Paper { pattern: Pattern::Bursty, seed: 9 })
            .horizon_s(300.0)
            .seeds(vec![1, 7])
            .build()
            .unwrap(),
        ScenarioSpec::builder("rt-small")
            .system("serverless-llm")
            .cluster(tiny_cluster())
            .workload(WorkloadSpec::SmallMulti { n_fns: 4, seed: 5 })
            .horizon_s(600.0)
            .seeds(vec![3])
            .build()
            .unwrap(),
        ScenarioSpec::builder("rt-insta")
            .system("instainfer")
            .hit_rate(0.8)
            .cluster(tiny_cluster())
            .workload(WorkloadSpec::Paper { pattern: Pattern::Normal, seed: 11 })
            .horizon_s(300.0)
            .seeds(vec![2])
            .build()
            .unwrap(),
        ScenarioSpec::builder("rt-zipf")
            .cluster(ClusterSpec::Uniform {
                nodes: 1,
                gpus_per_node: 4,
                containers_per_node: 8,
                trim_gpus: Some(3),
            })
            .workload(WorkloadSpec::ZipfFleetCov {
                fns: 16,
                skew: 1.2,
                head: Pattern::Bursty,
                tail: Pattern::Predictable,
                seed: 3,
            })
            .horizon_s(300.0)
            .seeds(vec![5])
            .bill_series(60.0)
            .build()
            .unwrap(),
        // Serverful needs a whole GPU per 13B function (26 GB of 48).
        ScenarioSpec::builder("rt-vllm")
            .system("vllm")
            .cluster(ClusterSpec::Uniform {
                nodes: 1,
                gpus_per_node: 4,
                containers_per_node: 8,
                trim_gpus: None,
            })
            .workload(WorkloadSpec::Breakdown13b { seed: 7 })
            .horizon_s(300.0)
            .seeds(vec![1])
            .build()
            .unwrap(),
    ];
    for spec in specs {
        let text = spec.to_json().dump();
        let reparsed =
            ScenarioSpec::from_json(&Json::parse(&text).expect("dump parses")).expect("round-trip");
        assert_eq!(reparsed, spec, "round-trip changed the spec: {text}");
        let a = scenario::run(&spec).unwrap();
        let b = scenario::run(&reparsed).unwrap();
        assert_eq!(a.runs.len(), b.runs.len());
        for (x, y) in a.runs.iter().zip(&b.runs) {
            assert_eq!(x.seed, y.seed);
            assert_eq!(x.metrics.outcomes.len(), y.metrics.outcomes.len());
            for (ox, oy) in x.metrics.outcomes.iter().zip(&y.metrics.outcomes) {
                assert_eq!(ox.id, oy.id, "{}: outcome order drifted", spec.name);
                assert_eq!(ox.ttft_s.to_bits(), oy.ttft_s.to_bits(), "{}", spec.name);
                assert_eq!(ox.e2e_s.to_bits(), oy.e2e_s.to_bits(), "{}", spec.name);
            }
            assert_eq!(
                x.cost.total_usd().to_bits(),
                y.cost.total_usd().to_bits(),
                "{}: cost diverged after a JSON round-trip",
                spec.name
            );
        }
    }
}

/// Every committed example scenario file parses, validates, and
/// round-trips (the CI dry-run step enforces the same from the binary).
#[test]
fn committed_example_scenarios_are_valid() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios");
    let mut seen = 0;
    for entry in std::fs::read_dir(&dir).expect("examples/scenarios exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        seen += 1;
        let text = std::fs::read_to_string(&path).unwrap();
        let json = Json::parse(&text).unwrap_or_else(|e| panic!("{path:?}: {e}"));
        let specs = scenario::specs_from_json(&json).unwrap_or_else(|e| panic!("{path:?}: {e}"));
        assert!(!specs.is_empty(), "{path:?}");
        for spec in &specs {
            spec.validate().unwrap_or_else(|e| panic!("{path:?} '{}': {e}", spec.name));
            let rt = ScenarioSpec::from_json(&Json::parse(&spec.to_json().dump()).unwrap())
                .unwrap();
            assert_eq!(&rt, spec, "{path:?}");
        }
    }
    assert!(seen >= 5, "expected the committed example set, found {seen} files");
}

/// The paper_latency example reproduces the experiment suite's values:
/// its ServerlessLoRA cell equals a direct engine run of the same
/// (config, workload, cluster, seed) bit-for-bit.
#[test]
fn paper_latency_example_matches_direct_run() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios");
    let text = std::fs::read_to_string(dir.join("paper_latency.json")).unwrap();
    let specs = scenario::specs_from_json(&Json::parse(&text).unwrap()).unwrap();
    let lora = specs
        .iter()
        .find(|s| s.name.contains("serverless-lora"))
        .expect("flagship cell present");
    // Shrink the horizon so the parity check stays test-suite cheap —
    // the spec fully describes the run, so this is still the same path.
    let mut quick = lora.clone();
    quick.horizon_s = 900.0;
    let report = scenario::run(&quick).unwrap();
    let run = &report.runs[0];

    let w = serverless_lora::sim::workloads::paper_workload(Pattern::Normal, 900.0, 11);
    let (m, c, _) = Engine::new(
        SystemConfig::serverless_lora(),
        serverless_lora::cluster::Cluster::paper_multinode(),
        w,
        1,
    )
    .run();
    assert_eq!(run.metrics.outcomes.len(), m.outcomes.len());
    assert_eq!(run.metrics.ttft().mean.to_bits(), m.ttft().mean.to_bits());
    assert_eq!(run.cost.total_usd().to_bits(), c.total_usd().to_bits());
}

/// A counting observer sees exactly the engine's own accounting: one
/// completion per outcome, one bill sample per `stats.bill_samples`,
/// plus keep-alive and class-transition traffic on a churny run.
#[derive(Default)]
struct Counts {
    completions: usize,
    bill_samples: usize,
    bill_dt_s: f64,
    reclasses: usize,
    initial_reclasses: usize,
    warm: usize,
    cold: usize,
    finished: usize,
}

struct CountingObserver(Arc<Mutex<Counts>>);

impl Observer for CountingObserver {
    fn on_request_complete(&mut self, _t: f64, _o: &RequestOutcome) {
        self.0.lock().unwrap().completions += 1;
    }

    fn on_bill_sample(&mut self, _t0: f64, dt_s: f64, _s: &AggregateBillSample) {
        let mut c = self.0.lock().unwrap();
        c.bill_samples += 1;
        c.bill_dt_s += dt_s;
    }

    fn on_gpu_reclass(&mut self, _t: f64, _g: GpuId, from: Option<BillClass>, to: BillClass) {
        let mut c = self.0.lock().unwrap();
        c.reclasses += 1;
        if from.is_none() {
            c.initial_reclasses += 1;
        }
        assert_ne!(from, Some(to), "same-class updates must not fire the hook");
    }

    fn on_keepalive(&mut self, _t: f64, _f: usize, warm: bool) {
        let mut c = self.0.lock().unwrap();
        if warm {
            c.warm += 1;
        } else {
            c.cold += 1;
        }
    }

    fn on_finish(&mut self, end_s: f64) {
        assert!(end_s > 0.0);
        self.0.lock().unwrap().finished += 1;
    }
}

#[test]
fn attached_observer_sees_the_engines_accounting() {
    let mut cfg = SystemConfig::serverless_lora();
    cfg.keepalive_s = 20.0; // churn keep-alive so both transitions fire
    let w = serverless_lora::sim::workloads::paper_workload(Pattern::Bursty, 600.0, 9);
    let counts = Arc::new(Mutex::new(Counts::default()));
    let mut e = Engine::new(cfg, serverless_lora::cluster::Cluster::new(1, 2, 4), w, 1);
    e.attach_observer(Box::new(CountingObserver(counts.clone())));
    let (m, _, stats) = e.run();
    let c = counts.lock().unwrap();
    assert_eq!(c.completions, m.outcomes.len(), "one hook per outcome");
    assert_eq!(c.bill_samples as u64, stats.bill_samples, "one hook per bill sample");
    // Billing covers the whole horizon (maybe more if the run drained
    // past it) with no gaps.
    assert!(c.bill_dt_s >= 600.0 - 1e-6, "billed {} s of 600", c.bill_dt_s);
    assert_eq!(c.initial_reclasses, 2, "deploy-time classification of both GPUs");
    assert!(c.reclasses > 2, "exec/idle churn must transition classes");
    assert!(c.warm > 0, "keep-alive entries must fire");
    assert!(c.cold > 0, "keep-alive expiries must fire (20 s window)");
    assert_eq!(c.finished, 1);
}

/// Serverful runs never sample intervals; an attached observer sees
/// completions but zero bill samples — the documented contract.
#[test]
fn serverful_runs_emit_no_bill_samples_to_observers() {
    let w = serverless_lora::sim::workloads::paper_workload(Pattern::Normal, 600.0, 9);
    let counts = Arc::new(Mutex::new(Counts::default()));
    let mut e =
        Engine::new(SystemConfig::vllm(), serverless_lora::cluster::Cluster::new(1, 8, 16), w, 1);
    e.attach_observer(Box::new(CountingObserver(counts.clone())));
    let (m, _, stats) = e.run();
    let c = counts.lock().unwrap();
    assert!(c.completions > 0 && c.completions == m.outcomes.len());
    assert_eq!(stats.bill_samples, 0);
    assert_eq!(c.bill_samples, 0, "serverful billing is flat — no interval samples");
}

/// Attaching observers must not change the simulation: metrics and cost
/// stay bit-identical to an unobserved run.
#[test]
fn observers_cannot_perturb_the_run() {
    let w = serverless_lora::sim::workloads::paper_workload(Pattern::Bursty, 600.0, 9);
    let (m0, c0, _) = Engine::new(
        SystemConfig::serverless_lora(),
        serverless_lora::cluster::Cluster::new(1, 2, 4),
        w.clone(),
        1,
    )
    .run();
    let counts = Arc::new(Mutex::new(Counts::default()));
    let mut e = Engine::new(
        SystemConfig::serverless_lora(),
        serverless_lora::cluster::Cluster::new(1, 2, 4),
        w,
        1,
    );
    e.attach_observer(Box::new(CountingObserver(counts)));
    e.enable_bill_series(60.0);
    let out = e.run_full();
    assert_eq!(m0.ttft().mean.to_bits(), out.metrics.ttft().mean.to_bits());
    assert_eq!(c0.total_usd().to_bits(), out.cost.total_usd().to_bits());
    assert!(out.bill_series.is_some());
}

/// Rejection paths surface as errors from the public entry point too
/// (not just `validate`): `run` refuses an invalid spec.
#[test]
fn run_refuses_invalid_specs() {
    let mut spec = ScenarioSpec::builder("bad")
        .cluster(tiny_cluster())
        .horizon_s(120.0)
        .build()
        .unwrap();
    spec.seeds.clear();
    assert!(scenario::run(&spec).is_err());
    let mut spec2 = ScenarioSpec::builder("bad2").cluster(tiny_cluster()).build().unwrap();
    spec2.system = SystemSpec::new("not-a-system");
    let err = scenario::run(&spec2).unwrap_err();
    let msg = err.to_string();
    for id in SYSTEM_IDS {
        assert!(msg.contains(id), "error must list '{id}': {msg}");
    }
}
