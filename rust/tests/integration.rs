//! Cross-module integration tests: full simulations at reduced scale
//! asserting the paper's *ordering* claims end-to-end, plus invariant
//! checks that span coordinator + cluster + sim.

use serverless_lora::artifact::{ArtifactKind, FunctionSpec, ModelProfile};
use serverless_lora::cluster::Cluster;
use serverless_lora::coordinator::{
    DynamicOffloader, FunctionDemand, Placement, PreloadScheduler,
};
use serverless_lora::cost::relative_cost_effectiveness;
use serverless_lora::sharing::BackboneRegistry;
use serverless_lora::sim::workloads::{paper_workload, throughput_workload};
use serverless_lora::sim::{Engine, SystemConfig, Workload};
use serverless_lora::trace::Pattern;
use serverless_lora::util::rng::Pcg64;

fn run(cfg: SystemConfig, w: Workload, gpus: usize) -> (
    serverless_lora::metrics::RunMetrics,
    serverless_lora::cost::CostTracker,
    serverless_lora::sim::RunStats,
) {
    Engine::new(cfg, Cluster::new(1, gpus, 2 * gpus), w, 7).run()
}

// ---------------------------------------------------------------- headline

/// The abstract's headline: TTFT reduced up to ~86% (≈ 4.7–7.1×) vs the
/// serverless baselines. At our reduced scale we require ≥ 2× on the mean.
#[test]
fn headline_ttft_reduction() {
    let w = paper_workload(Pattern::Normal, 2400.0, 5);
    let (lora, _, _) = run(SystemConfig::serverless_lora(), w.clone(), 16);
    let (sllm, _, _) = run(SystemConfig::serverless_llm(), w.clone(), 16);
    let (insta, _, _) = run(SystemConfig::instainfer(Pattern::Normal), w, 16);
    assert!(
        sllm.ttft().mean / lora.ttft().mean > 2.0,
        "vs ServerlessLLM: {:.2}x",
        sllm.ttft().mean / lora.ttft().mean
    );
    assert!(
        insta.ttft().mean / lora.ttft().mean > 2.0,
        "vs InstaInfer: {:.2}x",
        insta.ttft().mean / lora.ttft().mean
    );
}

/// The abstract's cost headline: monetary cost cut by a multiple vs the
/// serverless baselines.
#[test]
fn headline_cost_reduction() {
    let w = paper_workload(Pattern::Normal, 2400.0, 5);
    let (_, lc, _) = run(SystemConfig::serverless_lora(), w.clone(), 16);
    let (_, sc, _) = run(SystemConfig::serverless_llm(), w.clone(), 16);
    let (_, ic, _) = run(SystemConfig::instainfer(Pattern::Normal), w, 16);
    assert!(
        sc.total_usd() / lc.total_usd() > 1.5,
        "vs ServerlessLLM: {:.2}x",
        sc.total_usd() / lc.total_usd()
    );
    assert!(
        ic.total_usd() / lc.total_usd() > 1.5,
        "vs InstaInfer: {:.2}x",
        ic.total_usd() / lc.total_usd()
    );
}

/// Fig. 9 / Table 1: ServerlessLoRA's relative cost-effectiveness beats
/// every baseline on every arrival pattern.
#[test]
fn cost_effectiveness_wins_every_pattern() {
    for pattern in Pattern::ALL {
        let w = paper_workload(pattern, 2400.0, 5);
        let (vm, vc, _) = run(SystemConfig::vllm(), w.clone(), 16);
        let rel = |cfg: SystemConfig| {
            let (m, c, _) = run(cfg, w.clone(), 16);
            relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )
        };
        let lora = rel(SystemConfig::serverless_lora());
        assert!(lora > 1.0, "{}: lora rel-CE {lora}", pattern.name());
        for cfg in [
            SystemConfig::dlora(),
            SystemConfig::serverless_llm(),
            SystemConfig::instainfer(pattern),
        ] {
            let name = cfg.name;
            let other = rel(cfg);
            assert!(
                lora > other,
                "{}: {name} {other} >= lora {lora}",
                pattern.name()
            );
        }
    }
}

// ------------------------------------------------------------- conservation

/// Request conservation across every system and pattern: arrived ==
/// completed (the simulator must never lose or duplicate requests).
#[test]
fn request_conservation_all_systems() {
    let w = paper_workload(Pattern::Bursty, 1200.0, 9);
    let n = w.requests.len();
    for cfg in [
        SystemConfig::serverless_lora(),
        SystemConfig::serverless_llm(),
        SystemConfig::instainfer(Pattern::Bursty),
        SystemConfig::vllm(),
        SystemConfig::dlora(),
        SystemConfig::predictive(),
        SystemConfig::nbs(),
        SystemConfig::npl(),
        SystemConfig::ndo(),
        SystemConfig::nab(1),
        SystemConfig::nab(2),
        SystemConfig::nab(3),
    ] {
        let name = cfg.name;
        let (m, _, _) = run(cfg, w.clone(), 16);
        assert_eq!(m.outcomes.len(), n, "{name} lost requests");
        let mut ids: Vec<u64> = m.outcomes.iter().map(|o| o.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n, "{name} duplicated requests");
    }
}

/// Memory safety under sustained saturation: the ledgers' OOM checks
/// never fire as panics (over-commit is impossible by construction).
#[test]
fn saturation_never_overcommits() {
    let w = throughput_workload(180.0, 3);
    for cfg in [SystemConfig::serverless_lora(), SystemConfig::serverless_llm()] {
        let (m, _, _) = run(cfg, w.clone(), 2);
        assert!(!m.outcomes.is_empty());
    }
}

// ---------------------------------------------------- property-based sweeps

/// Property sweep: random small deployments — the preload plan NEVER
/// exceeds any capacity and NEVER violates placement rules.
#[test]
fn preload_plan_invariants_random_sweep() {
    let mut rng = Pcg64::new(0xBEEF);
    for trial in 0..40 {
        let n_fns = 1 + rng.below(10);
        let n_gpus = 1 + rng.below(4);
        let n_ctrs = 1 + rng.below(4);
        let demands: Vec<FunctionDemand> = (0..n_fns)
            .map(|i| {
                let model = if rng.f64() < 0.5 {
                    ModelProfile::llama2_7b()
                } else {
                    ModelProfile::llama2_13b()
                };
                FunctionDemand {
                    spec: FunctionSpec::new(i, model, i % 4),
                    rate: rng.uniform(0.001, 0.5),
                }
            })
            .collect();
        let cluster = Cluster::new(1, n_gpus, n_ctrs);
        let registry = BackboneRegistry::new();
        let plan = PreloadScheduler::default().plan(&demands, &cluster, &registry);

        // Capacity per GPU (shared backbones paid once per model).
        for g in cluster.gpu_ids() {
            let mut used = 0.0;
            let mut paid_models = std::collections::BTreeSet::new();
            for d in &plan.decisions {
                if d.placement == Placement::Gpu(g) {
                    if d.kind == ArtifactKind::Backbone {
                        let model = demands
                            .iter()
                            .find(|x| x.spec.id == d.function)
                            .unwrap()
                            .spec
                            .model
                            .name;
                        if paid_models.insert(model) {
                            used += d.size_gb;
                        }
                    } else {
                        used += d.size_gb;
                    }
                }
            }
            assert!(
                used <= cluster.gpu(g).free_gb() + 1e-6,
                "trial {trial}: GPU {g} overcommitted {used}"
            );
        }
        // Placement rules.
        for d in &plan.decisions {
            match (d.kind, d.placement) {
                (ArtifactKind::Library, Placement::Gpu(_)) => {
                    panic!("trial {trial}: library on GPU")
                }
                (ArtifactKind::CudaKernel, Placement::Container(_)) => {
                    panic!("trial {trial}: kernel in container")
                }
                _ => {}
            }
        }
        // Apply must succeed exactly as planned (no panic).
        let mut c2 = Cluster::new(1, n_gpus, n_ctrs);
        let mut r2 = BackboneRegistry::new();
        PreloadScheduler::default().apply(&plan, &demands, &mut c2, &mut r2);
    }
}

/// Property sweep: the offloader frees at least the requested amount or
/// exhausts every evictable artifact, never touching protected functions.
#[test]
fn offloader_invariants_random_sweep() {
    let mut rng = Pcg64::new(0xF00D);
    for trial in 0..60 {
        let mut cluster = Cluster::new(1, 1, 1);
        let mut registry = BackboneRegistry::new();
        let g = cluster.gpu_ids()[0];
        let n_fns = 1 + rng.below(8);
        for f in 0..n_fns {
            let _ = cluster.gpu_mut(g).place_artifact(
                f,
                ArtifactKind::Adapter,
                rng.uniform(0.05, 0.4),
            );
            let _ = cluster.gpu_mut(g).place_artifact(
                f,
                ArtifactKind::CudaKernel,
                rng.uniform(0.2, 0.8),
            );
        }
        if rng.f64() < 0.5 {
            registry
                .load(&mut cluster, "llama2-7b", 13.5, g)
                .unwrap();
        }
        let protected = vec![0usize];
        let free_before = cluster.gpu(g).free_gb();
        let need = free_before + rng.uniform(0.1, 5.0);
        let evictable_total: f64 = DynamicOffloader::evictable(
            &cluster, &registry, g, &protected, |_, _| 1.0,
        )
        .iter()
        .map(|e| e.size_gb)
        .sum();
        let noise = rng.uniform(0.1, 10.0);
        let plan = DynamicOffloader::free(
            &mut cluster,
            &mut registry,
            g,
            need,
            &protected,
            move |f, _| noise * (1.0 + f.unwrap_or(0) as f64),
            None,
        );
        let free_after = cluster.gpu(g).free_gb();
        if plan.satisfied {
            assert!(
                free_after >= need - 1e-6,
                "trial {trial}: satisfied but {free_after} < {need}"
            );
        } else {
            assert!(
                (free_after - (free_before + evictable_total)).abs() < 1e-6,
                "trial {trial}: unsatisfied but not fully drained"
            );
        }
        // Protected artifacts intact.
        assert!(cluster.gpu(g).has_artifact(0, ArtifactKind::Adapter));
        assert!(cluster.gpu(g).has_artifact(0, ArtifactKind::CudaKernel));
    }
}

/// Property sweep: the batcher never admits a batch whose predicted TTFT
/// (Eq. 2) violates the SLO, for random queue states.
#[test]
fn batcher_never_plans_slo_violation() {
    use serverless_lora::coordinator::{BatchQueue, Queued};
    let mut rng = Pcg64::new(0xCAFE);
    for _ in 0..200 {
        let model = if rng.f64() < 0.5 {
            ModelProfile::llama2_7b()
        } else {
            ModelProfile::llama2_13b()
        };
        let mut q = BatchQueue::new(0, &model);
        let n = 1 + rng.below(120);
        for i in 0..n {
            q.push(Queued { request: i as u64, arrival_s: rng.uniform(0.0, 2.0) });
        }
        let batch = q.take_batch(usize::MAX);
        assert!(
            q.predicted_ttft(batch.len()) <= q.slo_s + 1e-9,
            "batch {} exceeds SLO plan",
            batch.len()
        );
    }
}

/// Simulator determinism across systems: same seed ⇒ identical metrics.
#[test]
fn determinism_sweep() {
    let w = paper_workload(Pattern::Bursty, 900.0, 11);
    for cfg in [SystemConfig::serverless_lora(), SystemConfig::instainfer(Pattern::Bursty)] {
        let (m1, c1, _) = run(cfg.clone(), w.clone(), 8);
        let (m2, c2, _) = run(cfg, w.clone(), 8);
        assert_eq!(m1.outcomes.len(), m2.outcomes.len());
        assert_eq!(m1.ttft().mean.to_bits(), m2.ttft().mean.to_bits());
        assert_eq!(c1.total_usd().to_bits(), c2.total_usd().to_bits());
    }
}

// ------------------------------------------------------- golden parity

/// FNV-1a over the full outcome stream + billing: any behavioral drift in
/// the engine/policy stack changes this digest.
fn fingerprint(
    m: &serverless_lora::metrics::RunMetrics,
    c: &serverless_lora::cost::CostTracker,
) -> u64 {
    let mut h = serverless_lora::util::hash::Fnv1a::new();
    for o in &m.outcomes {
        h.write_u64(o.id);
        h.write_u64(o.ttft_s.to_bits());
        h.write_u64(o.e2e_s.to_bits());
        h.write_u64(o.tpot_s.to_bits());
        h.write_u64(o.batch_size as u64);
    }
    h.write_u64(c.total_usd().to_bits());
    h.finish()
}

fn golden_systems() -> Vec<SystemConfig> {
    vec![
        SystemConfig::serverless_lora(),
        SystemConfig::serverless_llm(),
        SystemConfig::instainfer(Pattern::Normal),
        SystemConfig::vllm(),
        SystemConfig::dlora(),
        SystemConfig::predictive(),
        SystemConfig::nbs(),
        SystemConfig::npl(),
        SystemConfig::ndo(),
        SystemConfig::nab(1),
        SystemConfig::nab(2),
        SystemConfig::nab(3),
    ]
}

/// Golden fingerprint test: per-system TTFT/cost digests over a fixed
/// `(SystemConfig, Workload, seed)` triple.
///
/// The golden file bootstraps itself: on first run (or with
/// `UPDATE_GOLDEN=1`) the digests are written to
/// `tests/golden/sim_fingerprints.json`; afterwards any refactor that
/// changes a single outcome bit for any pre-existing system fails here.
#[test]
fn golden_fingerprints_stable() {
    let w = paper_workload(Pattern::Normal, 1200.0, 5);
    let mut lines = Vec::new();
    for cfg in golden_systems() {
        let name = cfg.name;
        let (m1, c1, _) = run(cfg.clone(), w.clone(), 16);
        let (m2, c2, _) = run(cfg, w.clone(), 16);
        let (f1, f2) = (fingerprint(&m1, &c1), fingerprint(&m2, &c2));
        assert_eq!(f1, f2, "{name}: nondeterministic fingerprint");
        lines.push(format!("  \"{name}\": \"{f1:016x}\""));
    }
    let doc = format!("{{\n{}\n}}\n", lines.join(",\n"));

    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/golden");
    let path = dir.join("sim_fingerprints.json");
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    if update || !path.exists() {
        std::fs::create_dir_all(&dir).expect("create golden dir");
        std::fs::write(&path, &doc).expect("write golden file");
        eprintln!("golden fingerprints written to {}", path.display());
        return;
    }
    let stored = std::fs::read_to_string(&path).expect("read golden file");
    assert_eq!(
        stored, doc,
        "metrics digests drifted from {} — if the change is intentional, \
         regenerate with UPDATE_GOLDEN=1",
        path.display()
    );
}

// ------------------------------------------------ engine index invariants

/// Multi-seed invariant sweep: the engine's incremental dispatch-state
/// indexes (per-GPU Loading/Prefill counts, per-function in-flight
/// counts, the active dispatch-candidate set, the blocked map, the
/// single armed keep-alive sweep) must equal their brute-force
/// recomputation at arbitrary points mid-run. NDO runs the blocking
/// offload policy, so the blocked map is exercised under saturation.
#[test]
fn engine_indexes_match_bruteforce_mid_run() {
    for cfg in [SystemConfig::serverless_lora(), SystemConfig::ndo()] {
        for seed in [1u64, 11] {
            let w = paper_workload(Pattern::Bursty, 900.0, seed);
            let n = w.requests.len();
            let mut e = Engine::new(cfg.clone(), Cluster::new(1, 4, 8), w, seed);
            let mut steps: u64 = 0;
            while e.step() {
                steps += 1;
                if steps % 9 == 0 {
                    e.check_indexes();
                }
            }
            e.check_indexes();
            let (m, _, stats) = e.finish();
            assert_eq!(m.outcomes.len(), n, "{} lost requests", cfg.name);
            assert!(stats.events_processed as usize >= n);
        }
    }
}

/// Event-queue hygiene under saturation: keep-alive sweeps track expiry
/// windows (not completions — the queue used to gain one `KeepaliveCheck`
/// per completion), streamed arrivals keep the queue a small fraction of
/// the trace length, and superseded events are cancelled outright — the
/// timing wheel's peak length counts only live work.
#[test]
fn event_queue_hygiene_under_saturation() {
    let w = throughput_workload(180.0, 3);
    let n = w.requests.len();
    let (m, _, stats) = run(SystemConfig::serverless_lora(), w, 4);
    assert_eq!(m.outcomes.len(), n);
    assert!(n > 1000, "saturation workload too small: {n}");
    assert!(
        stats.keepalive_checks <= 64,
        "keepalive sweeps grew with completions: {} for {} requests",
        stats.keepalive_checks,
        n
    );
    assert!(
        stats.events_cancelled > 0,
        "saturation must supersede (and cancel) scheduled events"
    );
    // Live-event envelope: 1 streamed arrival + ≤2 wakeups per function
    // + ≤1 tick per GPU + one LoadDone per in-flight batch (GPU memory
    // caps those) + 1 keep-alive sweep. Far below the trace length — and
    // below the old stale-entry bloat, which scaled with supersessions.
    assert!(
        stats.peak_event_queue < 1024,
        "peak live event queue {} vs {} requests",
        stats.peak_event_queue,
        n
    );
}

/// Multi-seed sweep: the parallel experiment runner must produce exactly
/// the sequential results, in the same order, for every system × seed.
#[test]
fn parallel_runner_matches_sequential() {
    use serverless_lora::exp::runner::parallel_map_with;
    let tasks: Vec<(SystemConfig, u64)> = [1u64, 7, 23]
        .into_iter()
        .flat_map(|seed| {
            [
                SystemConfig::serverless_lora(),
                SystemConfig::instainfer(Pattern::Bursty),
                SystemConfig::predictive(),
            ]
            .into_iter()
            .map(move |cfg| (cfg, seed))
        })
        .collect();
    let w = paper_workload(Pattern::Bursty, 600.0, 11);
    let job = |(cfg, seed): (SystemConfig, u64)| {
        let (m, c, _) = Engine::new(cfg, Cluster::new(1, 8, 16), w.clone(), seed).run();
        fingerprint(&m, &c)
    };
    let sequential = parallel_map_with(1, tasks.clone(), job);
    let parallel = parallel_map_with(4, tasks, job);
    assert_eq!(sequential, parallel, "parallel runner diverged from sequential");
}
