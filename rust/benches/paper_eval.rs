//! `cargo bench` — the paper-evaluation harness.
//!
//! Regenerates every table and figure from the paper's §6 (the experiment
//! registry in `serverless_lora::exp`) and prints the same rows/series the
//! paper reports, plus wall-clock per experiment. `criterion` is not
//! vendored in this environment, so this is a plain `harness = false`
//! bench binary.
//!
//! Alongside the printed tables it writes `BENCH_sim.json`: per-experiment
//! wall-clock, output digests, and headline metrics, so the perf
//! trajectory is tracked across PRs by machines as well as humans.
//!
//! Usage:
//!   cargo bench                 quick mode (1-hour traces)
//!   cargo bench -- --full       full mode (the paper's 4-hour traces)
//!   cargo bench -- fig6 tab2    run a subset
//!   cargo bench -- --jobs 4     fan independent sim runs over 4 threads
//!                               (identical tables, lower wall-clock)

use std::time::Instant;

use serverless_lora::exp;
use serverless_lora::util::hash::fnv1a;
use serverless_lora::util::json::{arr, num, obj, s, Json};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let jobs: usize = args
        .iter()
        .position(|a| a == "--jobs")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .or_else(|| {
            args.iter()
                .find_map(|a| a.strip_prefix("--jobs=").and_then(|v| v.parse().ok()))
        })
        .unwrap_or(1);
    exp::runner::set_jobs(jobs);
    // Experiment ids are the bare tokens, minus the value consumed by a
    // space-separated `--jobs N` (it would otherwise be dropped by the
    // registry filter anyway, but skipping it keeps the intent explicit).
    let jobs_value_idx = args
        .iter()
        .position(|a| a == "--jobs")
        .map(|i| i + 1)
        .unwrap_or(usize::MAX);
    let ids: Vec<&str> = args
        .iter()
        .enumerate()
        .filter(|&(i, a)| i != jobs_value_idx && !a.starts_with("--"))
        .map(|(_, a)| a.as_str())
        .filter(|a| exp::ALL_EXPERIMENTS.contains(a))
        .collect();
    let ids: Vec<&str> = if ids.is_empty() {
        exp::ALL_EXPERIMENTS.to_vec()
    } else {
        ids
    };

    println!(
        "ServerlessLoRA paper-evaluation bench ({} mode, {} experiments, {} job{})\n",
        if full { "FULL 4h" } else { "quick 1h" },
        ids.len(),
        jobs,
        if jobs == 1 { "" } else { "s" }
    );
    let t_all = Instant::now();
    let ran_fleet = ids.contains(&"fleet");
    let ran_tiers = ids.contains(&"tiers");
    let ran_faults = ids.contains(&"faults");
    let ran_coldstarts = ids.contains(&"coldstarts");
    let mut records: Vec<Json> = Vec::new();
    for id in ids {
        let t0 = Instant::now();
        let report = exp::run_experiment(id, !full);
        let wall = t0.elapsed().as_secs_f64();
        print!("{report}");
        println!("[{id} took {wall:.1}s]\n");
        records.push(obj(vec![
            ("id", s(id)),
            ("wall_s", num(wall)),
            ("out_bytes", num(report.len() as f64)),
            ("digest", s(&format!("{:016x}", fnv1a(report.as_bytes())))),
        ]));
    }
    let total = t_all.elapsed().as_secs_f64();
    println!("total bench time: {total:.1}s");

    let mut fields = vec![
        ("mode", s(if full { "full" } else { "quick" })),
        ("jobs", num(jobs as f64)),
        ("total_s", num(total)),
        ("experiments", arr(records)),
        ("headline", exp::headline_json()),
    ];
    if ran_fleet {
        // Engine-scaling record (largest fleet configuration): events/s
        // and peak event-queue length, tracked across PRs. Reuses the
        // sweep's measurement — no extra simulation.
        fields.push(("fleet", exp::fleet::fleet_json(!full)));
    }
    if ran_tiers {
        // Tiered-store record (bursty reference cell): tier hit mix and
        // link re-time counts, tracked across PRs. Reuses the sweep's
        // measurement — no extra simulation.
        fields.push(("tiers", exp::tiers::tiers_json(!full)));
    }
    if ran_faults {
        // Fault-injection record (fast-failure reference cell): goodput,
        // TTFT degradation and recovery counters, tracked across PRs.
        // Reuses the sweep's measurement — no extra simulation.
        fields.push(("faults", exp::faults::faults_json(!full)));
    }
    if ran_coldstarts {
        // Cold-start strategy record (shortest keep-alive column):
        // snapshot-restore repeat-cold speedup + surcharge and pipelined
        // first-touch speedup vs the tiered baseline, tracked across
        // PRs. Reuses the sweep's measurement — no extra simulation.
        fields.push(("coldstarts", exp::coldstarts::coldstarts_json(!full)));
    }
    let doc = obj(fields);
    let path = "BENCH_sim.json";
    match std::fs::write(path, doc.dump() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
