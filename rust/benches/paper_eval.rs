//! `cargo bench` — the paper-evaluation harness.
//!
//! Regenerates every table and figure from the paper's §6 (the experiment
//! registry in `serverless_lora::exp`) and prints the same rows/series the
//! paper reports, plus wall-clock per experiment. `criterion` is not
//! vendored in this environment, so this is a plain `harness = false`
//! bench binary.
//!
//! Usage:
//!   cargo bench                 quick mode (1-hour traces)
//!   cargo bench -- --full       full mode (the paper's 4-hour traces)
//!   cargo bench -- fig6 tab2    run a subset

use std::time::Instant;

use serverless_lora::exp;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let ids: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with("--"))
        .map(String::as_str)
        .filter(|a| exp::ALL_EXPERIMENTS.contains(a))
        .collect();
    let ids: Vec<&str> = if ids.is_empty() {
        exp::ALL_EXPERIMENTS.to_vec()
    } else {
        ids
    };

    println!(
        "ServerlessLoRA paper-evaluation bench ({} mode, {} experiments)\n",
        if full { "FULL 4h" } else { "quick 1h" },
        ids.len()
    );
    let t_all = Instant::now();
    for id in ids {
        let t0 = Instant::now();
        let report = exp::run_experiment(id, !full);
        print!("{report}");
        println!("[{id} took {:.1}s]\n", t0.elapsed().as_secs_f64());
    }
    println!("total bench time: {:.1}s", t_all.elapsed().as_secs_f64());
}
