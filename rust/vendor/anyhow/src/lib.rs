//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The real crate is not vendored in this build environment (no network
//! access to crates.io), so this shim implements exactly the surface the
//! workspace uses: [`Error`], [`Result`], the [`anyhow!`] macro, and the
//! [`Context`] extension trait on `Result`/`Option`. Semantics mirror
//! upstream: `Error` is a dynamic error with a message chain, any
//! `std::error::Error + Send + Sync` converts into it via `?`, and
//! context wraps the message front-to-back.

use std::fmt;

/// Dynamic error type: a display message plus an optional source chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Wrap with higher-level context, preserving the source chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    pub fn root_cause(&self) -> &str {
        self.msg.rsplit(": ").next().unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source.as_deref().and_then(|e| e.source());
        while let Some(e) = src {
            write!(f, "\nCaused by: {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

// Like upstream anyhow, `Error` deliberately does NOT implement
// `std::error::Error` — that is what makes this blanket `From` legal.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("...")` / `anyhow!("x = {}", x)` → [`Error`].
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// `bail!(...)` — early-return an error (provided for completeness).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Context extension on fallible values.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn io_fail() -> std::result::Result<(), std::io::Error> {
        Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"))
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            io_fail()?;
            Ok(())
        }
        assert!(inner().unwrap_err().to_string().contains("gone"));
    }

    #[test]
    fn context_prepends() {
        let e = io_fail().context("reading manifest").unwrap_err();
        assert_eq!(e.to_string(), "reading manifest: gone");
        let e2 = io_fail().with_context(|| format!("pass {}", 2)).unwrap_err();
        assert_eq!(e2.to_string(), "pass 2: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(3u32).context("missing").unwrap(), 3);
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        let b: Error = anyhow!("x = {}", 7);
        let c: Error = anyhow!(String::from("owned"));
        assert_eq!(a.to_string(), "plain");
        assert_eq!(b.to_string(), "x = 7");
        assert_eq!(c.to_string(), "owned");
    }
}
