//! END-TO-END driver (DESIGN.md exp "e2e"): load the real AOT-compiled
//! tiny-Llama LoRA model, serve batched requests for all four adapters
//! over the live PJRT runtime through the fill-or-expire batcher, and
//! report latency/throughput — proving all three layers compose:
//!
//!   L1 Pallas kernels → L2 JAX graphs → HLO text → L3 Rust serving.
//!
//! Requires `make artifacts`. Run:
//!   cargo run --release --example e2e_serving [-- <n_requests>]

use std::time::{Duration, Instant};

use serverless_lora::runtime::server::{spawn, LiveRequest, ServerConfig};
use serverless_lora::runtime::Manifest;
use serverless_lora::util::rng::Pcg64;
use serverless_lora::util::stats::summarize;

fn main() -> anyhow::Result<()> {
    let n: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(24);
    let dir = Manifest::default_dir("llama-tiny");
    anyhow::ensure!(
        dir.join("manifest.json").exists(),
        "artifacts missing — run `make artifacts` first"
    );
    let manifest = Manifest::load(&dir)?;
    println!(
        "e2e: serving {} ({} params, {} LoRA adapters) on PJRT CPU",
        manifest.model, manifest.dims.param_count, manifest.n_adapters
    );

    let (tx, rx) = spawn(
        dir,
        ServerConfig { max_batch: 8, batch_delay: Duration::from_millis(30) },
    );

    // GSM8K-ish workload: variable prompts, 8-24 new tokens, all adapters.
    let mut rng = Pcg64::new(2026);
    let t0 = Instant::now();
    for i in 0..n as u64 {
        let plen = 6 + rng.below(10);
        let prompt: Vec<i32> = (0..plen)
            .map(|_| rng.below(manifest.dims.vocab) as i32)
            .collect();
        tx.send(LiveRequest {
            id: i,
            adapter: rng.below(manifest.n_adapters),
            prompt,
            max_new_tokens: 8 + rng.below(17),
        })?;
        // Mild burstiness in arrival.
        if i % 6 == 5 {
            std::thread::sleep(Duration::from_millis(40));
        }
    }
    drop(tx);

    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    let mut e2es = Vec::new();
    let mut tokens = 0usize;
    let mut batches = std::collections::BTreeMap::new();
    while let Ok(r) = rx.recv_timeout(Duration::from_secs(600)) {
        tokens += r.tokens.len();
        ttfts.push(r.ttft.as_secs_f64());
        tpots.push(r.tpot.as_secs_f64());
        e2es.push(r.e2e.as_secs_f64());
        *batches.entry(r.batch_size).or_insert(0usize) += 1;
        if ttfts.len() == n {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    anyhow::ensure!(ttfts.len() == n, "served {}/{} requests", ttfts.len(), n);

    let (st, sp, se) = (summarize(&ttfts), summarize(&tpots), summarize(&e2es));
    println!("served {n} requests in {wall:.2}s  ({tokens} tokens generated)");
    println!(
        "  TTFT  mean {:.1} ms   p50 {:.1}   p99 {:.1}",
        st.mean * 1e3, st.p50 * 1e3, st.p99 * 1e3
    );
    println!(
        "  TPOT  mean {:.1} ms   p50 {:.1}   p99 {:.1}",
        sp.mean * 1e3, sp.p50 * 1e3, sp.p99 * 1e3
    );
    println!(
        "  E2E   mean {:.1} ms   p99 {:.1}",
        se.mean * 1e3, se.p99 * 1e3
    );
    println!(
        "  throughput: {:.1} req/s, {:.1} tok/s",
        n as f64 / wall,
        tokens as f64 / wall
    );
    println!("  batch-size histogram: {batches:?}");
    Ok(())
}
