//! Quickstart: the three things this library does, in ~60 lines.
//!
//!  1. Plan pre-loading for a LoRA deployment (§4.1 PCKP greedy).
//!  2. Simulate serving a bursty trace and read the metrics.
//!  3. Run a *real* LoRA inference on the PJRT runtime with a shared
//!     backbone (requires `make artifacts` first).
//!
//! Run: `cargo run --release --example quickstart`

use serverless_lora::artifact::{FunctionSpec, ModelProfile};
use serverless_lora::cluster::Cluster;
use serverless_lora::coordinator::{FunctionDemand, PreloadScheduler};
use serverless_lora::runtime::{Engine, Manifest};
use serverless_lora::sharing::BackboneRegistry;
use serverless_lora::sim::workloads::paper_workload;
use serverless_lora::sim::{Engine as SimEngine, SystemConfig};
use serverless_lora::trace::Pattern;

fn main() -> anyhow::Result<()> {
    // 1 — plan pre-loading for four 7B LoRA functions on two GPUs.
    let demands: Vec<FunctionDemand> = (0..4)
        .map(|i| FunctionDemand {
            spec: FunctionSpec::new(i, ModelProfile::llama2_7b(), i),
            rate: 0.05,
        })
        .collect();
    let cluster = Cluster::new(1, 2, 4);
    let registry = BackboneRegistry::new();
    let plan = PreloadScheduler::default().plan(&demands, &cluster, &registry);
    println!(
        "preload plan: {} decisions, total value {:.2}",
        plan.decisions.len(),
        plan.total_value()
    );
    for d in plan.decisions.iter().take(6) {
        println!(
            "  fn{} {:?} -> {:?} ({:.2} GB)",
            d.function, d.kind, d.placement, d.size_gb
        );
    }

    // 2 — simulate a bursty hour and compare two systems.
    let w = paper_workload(Pattern::Bursty, 3600.0, 7);
    for cfg in [SystemConfig::serverless_lora(), SystemConfig::serverless_llm()] {
        let name = cfg.name;
        let (m, c, _) =
            SimEngine::new(cfg, Cluster::paper_multinode(), w.clone(), 1).run();
        println!(
            "{name:>16}: TTFT {:.0} ms | E2E {:.0} ms | cost ${:.2}",
            m.ttft().mean * 1000.0,
            m.e2e().mean * 1000.0,
            c.total_usd()
        );
    }

    // 3 — real inference through the AOT artifacts (if built).
    let dir = Manifest::default_dir("llama-tiny");
    if dir.join("manifest.json").exists() {
        let engine = Engine::load(dir)?;
        let f0 = engine.instance(0)?; // two isolated functions…
        let f1 = engine.instance(1)?; // …sharing one backbone (Arc)
        println!(
            "backbone refcount with 2 instances attached: {}",
            engine.backbone_refcount()
        );
        let out0 = engine.generate(&f0, &[vec![1, 2, 3, 4, 5]], 6)?;
        let out1 = engine.generate(&f1, &[vec![1, 2, 3, 4, 5]], 6)?;
        println!("adapter0 tokens: {:?}", out0[0]);
        println!("adapter1 tokens: {:?}", out1[0]);
    } else {
        println!("(run `make artifacts` to enable the real-runtime demo)");
    }
    Ok(())
}
