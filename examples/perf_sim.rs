use serverless_lora::cluster::Cluster;
use serverless_lora::sim::workloads::{paper_workload, throughput_workload};
use serverless_lora::sim::{Engine, SystemConfig};
use serverless_lora::trace::Pattern;
use std::time::Instant;
fn main() {
    // Saturating: 43k requests
    let w = throughput_workload(900.0, 3);
    let n = w.requests.len();
    let t0 = Instant::now();
    let (m, _, _) = Engine::new(SystemConfig::serverless_lora(), Cluster::new(1, 2, 8), w, 2).run();
    let dt = t0.elapsed().as_secs_f64();
    println!("saturating sim: {} requests in {:.3}s = {:.0} req/s sim-throughput (served {})", n, dt, n as f64/dt, m.outcomes.len());
    // 4h full-scale paper workload
    let w = paper_workload(Pattern::Bursty, 4.0*3600.0, 11);
    let n = w.requests.len();
    let t0 = Instant::now();
    let (m, _, _) = Engine::new(SystemConfig::serverless_lora(), Cluster::paper_multinode(), w, 1).run();
    let dt = t0.elapsed().as_secs_f64();
    println!("4h bursty sim: {} requests in {:.3}s (served {})", n, dt, m.outcomes.len());
}
