//! Runtime hot-path profile: decode-step cost split (execute vs host
//! round-trip of the KV cache) — feeds EXPERIMENTS.md §Perf.
use serverless_lora::runtime::{Engine, Manifest};
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let e = Engine::load(Manifest::default_dir("llama-tiny"))?;
    println!("engine: compile {:.1}s for {} executables, backbone upload {:.3}s ({} MB)",
        e.profile.compile_s, e.profile.n_executables, e.profile.backbone_upload_s,
        e.profile.backbone_bytes / 1_000_000);
    let inst = e.instance(0)?;
    for b in [1usize, 8] {
        let prompts: Vec<Vec<i32>> = (0..b).map(|i| vec![(i as i32)%100; 16]).collect();
        let t0 = Instant::now();
        let (logits, mut kv) = e.prefill(&inst, &prompts)?;
        let prefill_ms = t0.elapsed().as_secs_f64()*1e3;
        let mut next: Vec<i32> = logits.iter().map(|l| {
            let mut bi = 0; for (i,&x) in l.iter().enumerate() { if x > l[bi] { bi = i; } } bi as i32
        }).collect();
        let n = 32;
        let t0 = Instant::now();
        for _ in 0..n { let l = e.decode(&inst, &next, &mut kv)?; next = l.iter().map(|v| { let mut bi=0; for (i,&x) in v.iter().enumerate() { if x > v[bi] { bi=i; } } bi as i32}).collect(); }
        let tpot_ms = t0.elapsed().as_secs_f64()*1e3 / n as f64;
        println!("batch {b}: prefill {prefill_ms:.1} ms, decode {tpot_ms:.2} ms/step");
    }
    Ok(())
}
