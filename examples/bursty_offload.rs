//! Bursty-workload scenario focused on the Dynamic Offloader (§4.3):
//! a small 2-GPU deployment where pre-loaded artifacts and KV demand
//! collide, so serving bursts REQUIRES evicting idle artifacts.
//! Compares full ServerlessLoRA against the NDO ablation (block & wait).
//!
//! Run: `cargo run --release --example bursty_offload`

use serverless_lora::artifact::{FunctionSpec, ModelProfile};
use serverless_lora::cluster::Cluster;
use serverless_lora::sim::{Engine, SystemConfig, Workload};
use serverless_lora::trace::{merge, Pattern, TraceSpec};
use serverless_lora::util::table::{f, ms, Table};

fn workload() -> Workload {
    // 6 functions on 2 GPUs: artifacts + KV cannot all stay resident.
    let functions: Vec<FunctionSpec> = (0..6)
        .map(|i| FunctionSpec::new(i, ModelProfile::llama2_7b(), i % 4))
        .collect();
    let rates = vec![1.0 / 60.0; 6];
    let traces = functions
        .iter()
        .map(|fx| {
            TraceSpec::new(fx.id, Pattern::Bursty, rates[fx.id], 99 + fx.id as u64)
                .generate(3600.0)
        })
        .collect();
    Workload { functions, requests: merge(traces), duration_s: 3600.0, rates }
}

fn main() {
    println!("6x Llama2-7B LoRA functions squeezed onto 2 GPUs, bursty hour\n");
    let w = workload();
    println!("{} requests", w.requests.len());
    let mut t = Table::new(
        "Dynamic offloading under memory pressure",
        &["system", "TTFT", "p99 TTFT", "E2E", "offloads", "GB moved", "blocked"],
    );
    for cfg in [SystemConfig::serverless_lora(), SystemConfig::ndo()] {
        let name = cfg.name;
        let (m, _, s) = Engine::new(cfg, Cluster::new(1, 2, 6), w.clone(), 5).run();
        t.row(vec![
            name.into(),
            ms(m.ttft().mean),
            ms(m.ttft().p99),
            ms(m.e2e().mean),
            s.offload_events.to_string(),
            f(s.offloaded_gb),
            s.blocked_dispatches.to_string(),
        ]);
    }
    t.print();
    println!("\nNDO blocks dispatches until memory frees; the offloader evicts");
    println!("the least-valuable artifacts instead (Eq. 6/7 value-density greedy).");
}
