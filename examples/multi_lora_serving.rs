//! Multi-LoRA serving scenario (the paper's §1 motivation): many LoRA
//! functions fine-tuned from few backbones, under a realistic mixed
//! workload — shows how the coordinator shares backbones, plans
//! pre-loading by arrival rate, and what it costs vs the baselines.
//!
//! Run: `cargo run --release --example multi_lora_serving`

use serverless_lora::cluster::Cluster;
use serverless_lora::cost::relative_cost_effectiveness;
use serverless_lora::sim::workloads::{paper_workload, series_13b, series_7b};
use serverless_lora::sim::{Engine, SystemConfig};
use serverless_lora::trace::Pattern;
use serverless_lora::util::table::{f, ms, Table};

fn main() {
    let duration = 3600.0;
    println!("8 LoRA functions (4x Llama2-7B, 4x Llama2-13B) on 16 GPUs, 1h Normal trace\n");

    let w = paper_workload(Pattern::Normal, duration, 42);
    println!(
        "workload: {} requests across {} functions",
        w.requests.len(),
        w.functions.len()
    );

    // vLLM is the cost-effectiveness baseline (= 1).
    let (vm, vc, _) = Engine::new(
        SystemConfig::vllm(),
        Cluster::paper_multinode(),
        w.clone(),
        1,
    )
    .run();

    let mut t = Table::new(
        "Multi-LoRA serving comparison",
        &["system", "TTFT-7B", "TTFT-13B", "E2E", "cost($)", "rel-cost-eff"],
    );
    for cfg in [
        SystemConfig::vllm(),
        SystemConfig::dlora(),
        SystemConfig::serverless_llm(),
        SystemConfig::instainfer(Pattern::Normal),
        SystemConfig::serverless_lora(),
    ] {
        let name = cfg.name;
        let (m, c, stats) =
            Engine::new(cfg, Cluster::paper_multinode(), w.clone(), 1).run();
        t.row(vec![
            name.into(),
            ms(m.subset(&series_7b()).ttft().mean),
            ms(m.subset(&series_13b()).ttft().mean),
            ms(m.e2e().mean),
            f(c.total_usd()),
            f(relative_cost_effectiveness(
                m.e2e().mean,
                c.total_usd(),
                vm.e2e().mean,
                vc.total_usd(),
            )),
        ]);
        if name == "ServerlessLoRA" {
            println!(
                "ServerlessLoRA internals: {} preload decisions, {} offload events ({:.1} GB), {}/{} warm dispatches",
                stats.preload_decisions,
                stats.offload_events,
                stats.offloaded_gb,
                stats.warm_dispatches,
                stats.warm_dispatches + stats.cold_dispatches,
            );
        }
    }
    t.print();
}
